//! Iteration-level continuous-batching engine over the roofline GPU model.
//!
//! One loop iteration = one engine step (Orca-style): chunked prefill
//! tokens plus one decode token for every running sequence, costed by
//! `GpuModel::iteration`. Admission happens between steps via the
//! `Scheduler` under a feasibility check covering the batch cap and KV
//! memory — prediction-driven schedulers additionally *reserve* KV for
//! their predicted output (the paper's stall-free scheduling), which is
//! what saves them from mid-decode preemptions under pressure.
//!
//! # Event-horizon macro-stepping
//!
//! Between scheduling events a decode-only batch is piecewise
//! predictable: no admissions (the queue head stayed infeasible and
//! feasibility only tightens as KV fills), no completions before the
//! shortest remaining output, KV growth follows the context series. The
//! default [`StepMode::Macro`] engine therefore computes the distance to
//! the next event — earliest sequence completion, KV free-page
//! exhaustion, next arrival, sample-window boundary, scheduler quota
//! refresh ([`crate::sched::Scheduler::next_refresh_at`]), and the trace
//! horizon when `drain` is off — and advances every sequence that many
//! tokens in ONE loop iteration, costed in closed form by
//! [`GpuModel::iterations_bulk`]. The per-token path is retained as
//! [`StepMode::Micro`], the executable reference: `tests/macro_stepping.rs`
//! proves both modes agree on finished/preemptions/service/latency across
//! FCFS, VTC, and Equinox (see EXPERIMENTS.md §Perf for the invariants).

use super::gpu::{GpuModel, IterationMix};
use super::host::HostProfile;
use crate::core::{ClientId, ClientSlab, Request, RequestState};
use crate::kv::{KvCache, KvConfig};
use crate::metrics::{LatencyStats, ServiceTracker};
use crate::obs::{EventKind, NullRecorder, Recorder, TraceEvent, TraceRecorder};
use crate::predictor::{predict_request, PerfMap, Predictor};
use crate::sched::counters::{HfParams, HolisticCounters};
use crate::sched::{Actuals, Scheduler};
use crate::workload::Trace;
use std::sync::Arc;

/// How the engine advances stable decode batches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepMode {
    /// One decode token per loop iteration — the executable reference
    /// semantics; O(tokens) loop iterations per run.
    Micro,
    /// Event-horizon macro-stepping: advance a stable decode-only batch
    /// to the next scheduling event in one loop iteration — O(events)
    /// iterations per run, identical results (the default).
    Macro,
}

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub gpu: GpuModel,
    pub host: HostProfile,
    /// Timeline sample period (s) for util/rate series.
    pub sample_dt: f64,
    /// Safety cap on engine loop iterations (a macro-step counts one).
    pub max_iterations: u64,
    /// `true` (default): keep running after the trace horizon until all
    /// queues drain — every request completes. `false`: stop at the
    /// first loop iteration whose clock reaches `trace.horizon`,
    /// abandoning still-queued/running work (`finished` may be less than
    /// `total_requests`); use for steady-state measurements where the
    /// drain tail would wash out scheduler differences.
    pub drain: bool,
    /// Per-token reference vs event-horizon macro-stepping.
    pub step_mode: StepMode,
}

impl SimConfig {
    pub fn a100_7b_vllm() -> Self {
        SimConfig {
            gpu: GpuModel::a100_7b(),
            host: HostProfile::VLLM,
            sample_dt: 1.0,
            max_iterations: 20_000_000,
            drain: true,
            step_mode: StepMode::Macro,
        }
    }

    pub fn with_host(mut self, host: HostProfile) -> Self {
        self.host = host;
        self
    }

    pub fn with_gpu(mut self, gpu: GpuModel) -> Self {
        self.gpu = gpu;
        self
    }

    pub fn with_step_mode(mut self, mode: StepMode) -> Self {
        self.step_mode = mode;
        self
    }
}

/// A request resident in the running batch.
#[derive(Debug)]
struct Running {
    req: Request,
    prefill_done: u32,
    admitted_at: f64,
    /// ∫ util dt over this request's residency (SM-busy seconds).
    util_acc: f64,
    /// Σ iteration time over the residency — `util_acc / util_time` is
    /// the busy-time-weighted average utilization fed to `Actuals`.
    /// (Time-weighted rather than per-iteration-sample-weighted so a
    /// macro-step of `k` iterations accumulates it in O(1).)
    util_time: f64,
    /// KV tokens currently backed by pages.
    kv_tokens: u32,
}

/// Everything the experiment harness needs out of one run.
#[derive(Debug)]
pub struct SimResult {
    pub scheduler: String,
    pub latency: LatencyStats,
    /// Per-client latency stats, dense by client id; iterate with
    /// [`ClientSlab::iter`] (ascending id, same order the old `BTreeMap`
    /// gave).
    pub per_client_latency: ClientSlab<LatencyStats>,
    pub service: ServiceTracker,
    /// (time, utilization in [0,1]) samples.
    pub util_timeline: Vec<(f64, f64)>,
    /// Output tokens per second of wall time.
    pub output_tps: f64,
    /// Weighted-token service per second.
    pub weighted_tps: f64,
    /// Busy-time-weighted average GPU utilization.
    pub gpu_util: f64,
    pub finished: usize,
    pub total_requests: usize,
    pub preemptions: u64,
    /// Engine loop iterations actually executed (a macro-step counts 1).
    pub iterations: u64,
    /// Micro-equivalent iterations: a macro-step of `k` counts `k`. In
    /// `StepMode::Micro` this equals `iterations`; the macro/micro ratio
    /// `iter_equiv / iterations` is the macro-stepping win.
    pub iter_equiv: u64,
    /// Loop iterations that advanced more than one token (macro-steps).
    pub macro_steps: u64,
    /// Entries left in the preemption-rework watermark map at the end of
    /// the run — 0 after any fully drained run (completion removes the
    /// entry; regression guard for the unbounded-growth leak).
    pub rework_live: usize,
    /// Calibration-guard mode transitions observed during the run (0 for
    /// unguarded schedulers). Diagnostic only — deliberately NOT folded
    /// into fingerprints, which must stay comparable across guard
    /// configurations; the trace digest pins the transitions instead.
    pub guard_transitions: u64,
    /// Final per-client HF score from the scheduler-independent auditor
    /// (Jain over HF, §7.3.3).
    pub final_hf: Vec<(ClientId, f64)>,
    /// Per-sample-window set of backlogged clients (queued work), for the
    /// VTC-style bounded-discrepancy evaluation. Consecutive identical
    /// sets share one `Arc` allocation, so long drain phases (which
    /// sample the same backlog thousands of times) stay O(distinct sets)
    /// in memory instead of O(windows × clients).
    pub backlog_timeline: Vec<(f64, Arc<[ClientId]>)>,
    /// End of simulated time.
    pub wall: f64,
}

impl SimResult {
    pub fn jain_over_hf(&self) -> f64 {
        let xs: Vec<f64> = self.final_hf.iter().map(|(_, v)| *v).collect();
        crate::metrics::jain_index(&xs)
    }

    pub fn jain_over_service(&self) -> f64 {
        let xs: Vec<f64> =
            self.service.clients().iter().map(|c| self.service.total(*c)).collect();
        crate::metrics::jain_index(&xs)
    }

    /// Mean of Jain's index over per-window service rates — the
    /// *stability* view of fairness (Fig 12a): statistically identical
    /// tenants all end with equal totals, but an unfair scheduler serves
    /// them in lopsided bursts that windowed Jain exposes.
    pub fn windowed_jain(&self, window: f64) -> f64 {
        self.windowed_jain_until(window, self.wall)
    }

    /// Windowed Jain restricted to `t_max` (typically the trace horizon:
    /// during post-arrival drain every scheduler serves equal backlogs
    /// round-robin-ish, which would wash out the differences).
    pub fn windowed_jain_until(&self, window: f64, t_max: f64) -> f64 {
        let clients = self.service.clients();
        let t_end = t_max.min(self.wall);
        if clients.len() < 2 || t_end <= window {
            return 1.0;
        }
        let mut sum = 0.0;
        let mut n = 0usize;
        let mut t = window;
        while t <= t_end {
            let xs: Vec<f64> = clients
                .iter()
                .map(|c| self.service.curve(*c).map(|cv| cv.rate(t, window)).unwrap_or(0.0))
                .collect();
            if xs.iter().any(|&x| x > 0.0) {
                sum += crate::metrics::jain_index(&xs);
                n += 1;
            }
            t += window;
        }
        if n == 0 {
            1.0
        } else {
            sum / n as f64
        }
    }

    /// Maximal sampled intervals during which `client` had queued
    /// (backlogged) work, merged from the per-window backlog samples.
    /// The no-starvation conformance invariant is stated over these: a
    /// client continuously backlogged for longer than the starvation
    /// window must have received some service inside the interval.
    pub fn backlogged_intervals(&self, client: ClientId) -> Vec<(f64, f64)> {
        let mut out = Vec::new();
        let mut start: Option<f64> = None;
        let mut last = 0.0f64;
        for (t, set) in &self.backlog_timeline {
            if set.contains(&client) {
                if start.is_none() {
                    start = Some(*t);
                }
                last = *t;
            } else if let Some(s) = start.take() {
                out.push((s, last));
            }
        }
        if let Some(s) = start {
            out.push((s, last));
        }
        out
    }

    /// Every client that was backlogged in at least one sample window.
    pub fn ever_backlogged_clients(&self) -> Vec<ClientId> {
        let mut set = std::collections::BTreeSet::new();
        for (_, clients) in &self.backlog_timeline {
            set.extend(clients.iter().copied());
        }
        set.into_iter().collect()
    }

    /// Max over all client pairs of the co-backlogged service
    /// discrepancy — the multi-tenant generalisation of
    /// [`backlogged_diff_series`](SimResult::backlogged_diff_series),
    /// which the conformance harness checks against its bound for
    /// fairness-claiming schedulers.
    pub fn max_co_backlogged_diff(&self) -> f64 {
        let clients = self.service.clients();
        let mut worst = 0.0f64;
        for (i, &a) in clients.iter().enumerate() {
            for &b in clients.iter().skip(i + 1) {
                for d in self.backlogged_diff_series(a, b) {
                    worst = worst.max(d);
                }
            }
        }
        worst
    }

    /// The VTC-paper fairness quantity: |ΔS_a − ΔS_b| accumulated within
    /// maximal intervals where BOTH clients are backlogged (the bounded-
    /// discrepancy theorem is stated over such intervals — outside them a
    /// client may legitimately receive less because it demands less).
    /// Returns the sampled series across all co-backlogged windows.
    pub fn backlogged_diff_series(&self, a: ClientId, b: ClientId) -> Vec<f64> {
        let ca = self.service.curve(a);
        let cb = self.service.curve(b);
        let (Some(ca), Some(cb)) = (ca, cb) else { return Vec::new() };
        let mut series = Vec::new();
        let mut window_start: Option<(f64, f64, f64)> = None; // (t0, sa0, sb0)
        for (t, backlogged) in &self.backlog_timeline {
            let both = backlogged.contains(&a) && backlogged.contains(&b);
            match (both, window_start) {
                (true, None) => {
                    window_start = Some((*t, ca.at(*t), cb.at(*t)));
                }
                (true, Some((_, sa0, sb0))) => {
                    series.push(((ca.at(*t) - sa0) - (cb.at(*t) - sb0)).abs());
                }
                (false, Some(_)) => {
                    window_start = None;
                }
                (false, None) => {}
            }
        }
        series
    }
}

/// A request extracted from a failed replica, carrying the decode
/// progress the failed replica already rendered as service. The
/// destination engine re-generates those tokens (recompute-style
/// migration — KV never moves across replicas) but only credits service
/// and prefill past the watermark, so migrated work is re-priced as GPU
/// rework, never double-counted as delivered service.
#[derive(Debug, Clone)]
pub struct Orphan {
    pub req: Request,
    /// Rework watermark to install at the destination: output tokens the
    /// failed replica already credited. 0 for never-started requests.
    pub rework: u32,
}

/// One simulation run binding scheduler + predictor + workload.
pub struct Simulation<'a> {
    pub cfg: SimConfig,
    pub scheduler: &'a mut dyn Scheduler,
    pub predictor: &'a mut dyn Predictor,
    pub perfmap: PerfMap,
}

impl<'a> Simulation<'a> {
    pub fn new(
        cfg: SimConfig,
        scheduler: &'a mut dyn Scheduler,
        predictor: &'a mut dyn Predictor,
    ) -> Self {
        Simulation { cfg, scheduler, predictor, perfmap: PerfMap::default_a100_7b() }
    }

    /// Run the whole trace to completion — a thin driver over the
    /// resumable [`step_once`] entry point (the cluster driver uses the
    /// same stepper to interleave several engines deterministically).
    pub fn run(&mut self, trace: &Trace) -> SimResult {
        let mut st = RunState::start(&self.cfg, trace);
        while step_once(
            &self.cfg,
            &mut *self.scheduler,
            &mut *self.predictor,
            &mut self.perfmap,
            &mut st,
            None,
        ) {}
        let name = self.scheduler.name().to_string();
        st.into_result(&name)
    }

    /// `run` with a [`TraceRecorder`] of the given ring capacity attached:
    /// returns the result plus the merged event stream (canonical
    /// (t, replica=0, seq) order) and the ring-overflow drop count.
    pub fn run_traced(
        &mut self,
        trace: &Trace,
        capacity: usize,
    ) -> (SimResult, Vec<TraceEvent>, u64) {
        let mut st = RunState::start(&self.cfg, trace);
        st.set_recorder(Box::new(TraceRecorder::new(0, capacity)));
        while step_once(
            &self.cfg,
            &mut *self.scheduler,
            &mut *self.predictor,
            &mut self.perfmap,
            &mut st,
            None,
        ) {}
        let mut events = Vec::new();
        st.recorder_mut().drain_into(&mut events);
        let dropped = st.recorder_dropped();
        crate::obs::merge_events(&mut events);
        let name = self.scheduler.name().to_string();
        (st.into_result(&name), events, dropped)
    }
}

/// The engine's arrival stream: the shared seed trace plus arrivals
/// injected online behind it. Logically one sorted sequence
/// `seed ++ injected`, indexed by the run's `next_arrival` cursor.
///
/// The seed is an `Arc<[Request]>` shared with the `Trace` — seeding a
/// run is a refcount bump, not a deep copy of the request vector (the
/// seed cloned the full trace per run: per scheduler × per seed ×
/// per replica). Requests are cloned one at a time only as the cursor
/// consumes them.
#[derive(Debug)]
struct ArrivalStream {
    seed: Arc<[Request]>,
    injected: Vec<Request>,
}

impl ArrivalStream {
    fn from_seed(seed: Arc<[Request]>) -> ArrivalStream {
        ArrivalStream { seed, injected: Vec::new() }
    }

    fn len(&self) -> usize {
        self.seed.len() + self.injected.len()
    }

    fn get(&self, i: usize) -> Option<&Request> {
        if i < self.seed.len() {
            self.seed.get(i)
        } else {
            self.injected.get(i - self.seed.len())
        }
    }

    fn last_arrival(&self) -> Option<f64> {
        self.injected.last().or_else(|| self.seed.last()).map(|r| r.arrival)
    }

    fn push(&mut self, req: Request) {
        self.injected.push(req);
    }

    /// Take every entry as owned requests, leaving the stream empty.
    /// Seed entries are cloned (the Arc may be shared) — this is the
    /// replica-failover path only, never steady-state stepping.
    fn drain_owned(&mut self) -> Vec<Request> {
        let mut out: Vec<Request> = Vec::with_capacity(self.len());
        out.extend(self.seed.iter().cloned());
        out.append(&mut self.injected);
        self.seed = Arc::from(Vec::new());
        out
    }

    /// Replace the whole stream with `kept` (post-failover survivors).
    fn replace(&mut self, kept: Vec<Request>) {
        self.seed = Arc::from(Vec::new());
        self.injected = kept;
    }
}

/// Complete mid-run engine state: everything `Simulation::run`'s loop
/// used to hold in locals, extracted so a run is *resumable* — the
/// cluster driver (`crate::cluster`) interleaves N of these by stepping
/// the lagging engine, and feeds arrivals online via
/// [`RunState::inject`] instead of a pre-materialised trace.
pub struct RunState {
    kv: KvCache,
    running: Vec<Running>,
    /// Arrival stream, sorted by arrival time. `start` seeds the whole
    /// trace up front (shared, not copied); `start_empty` + `inject`
    /// appends online.
    pending: ArrivalStream,
    next_arrival: usize,
    horizon: f64,
    t: f64,
    iterations: u64,
    iter_equiv: u64,
    macro_steps: u64,
    preemptions: u64,
    finished: usize,
    latency: LatencyStats,
    per_client_latency: ClientSlab<LatencyStats>,
    service: ServiceTracker,
    auditor: HolisticCounters,
    peak_tps: f64,
    util_timeline: Vec<(f64, f64)>,
    backlog_timeline: Vec<(f64, Arc<[ClientId]>)>,
    // Reused scratch + interned last set: the per-window backlog
    // sample is allocation-free unless the set actually changed.
    backlog_scratch: Vec<ClientId>,
    last_backlog: Option<Arc<[ClientId]>>,
    win_start: f64,
    win_busy_util: f64, // ∫ util dt over busy time, current window
    busy_util_total: f64,
    total_output_tokens: u64,
    total_weighted: f64,
    last_batch_sig: u64,
    // Decode progress watermark for preempted requests: recomputed
    // tokens are GPU work but NOT newly delivered service — counting
    // them would credit the preempted tenant with phantom service.
    rework: std::collections::HashMap<crate::core::RequestId, u32>,
    // Hoisted victim-selection scratch: per-client resident KV footprint
    // of the running batch. Filled and sparsely reset (touched list)
    // inside one preemption decision — the seed allocated a fresh
    // `BTreeMap` per decision; the slab makes the steady-state stepping
    // path allocation-free once grown.
    fp_scratch: ClientSlab<u64>,
    fp_touched: Vec<ClientId>,
    /// Flight recorder — [`NullRecorder`] unless a caller attached a
    /// [`TraceRecorder`] via [`RunState::set_recorder`]. Every lifecycle
    /// edge calls through it; per-token and per-window capture is
    /// additionally gated on `enabled()` so tracing off costs one no-op
    /// virtual call per rare event and nothing on the token path.
    rec: Box<dyn Recorder>,
    /// Last observed calibration-guard mode code (`GuardMode::code`);
    /// `None` until the first completion of a guarded run. Edge-detected
    /// after each completion batch to emit `GuardTransition` events.
    last_guard_mode: Option<u32>,
    guard_transitions: u64,
    /// Terminal (max-iterations cap or horizon stop with drain off):
    /// stepping again is a no-op. A *drained* state is not terminal —
    /// injecting a later arrival revives it.
    done: bool,
}

impl RunState {
    /// Seed a run with a fully materialised trace (the single-engine
    /// path — `Simulation::run` uses exactly this). The trace's request
    /// slice is shared by `Arc`, not copied.
    pub fn start(cfg: &SimConfig, trace: &Trace) -> RunState {
        Self::with_pending(cfg, trace.requests.clone(), trace.horizon)
    }

    /// Seed an empty run whose arrivals are routed in later via
    /// [`RunState::inject`] (the cluster-replica path).
    pub fn start_empty(cfg: &SimConfig, horizon: f64) -> RunState {
        Self::with_pending(cfg, Arc::from(Vec::new()), horizon)
    }

    fn with_pending(cfg: &SimConfig, seed: Arc<[Request]>, horizon: f64) -> RunState {
        let kv_cfg = KvConfig {
            page_size: 16,
            total_pages: ((cfg.gpu.kv_token_capacity() as f64 * cfg.host.kv_fraction) as u64 / 16)
                .min(u32::MAX as u64) as u32,
        };
        RunState {
            kv: KvCache::new(kv_cfg),
            running: Vec::new(),
            pending: ArrivalStream::from_seed(seed),
            next_arrival: 0,
            horizon,
            t: 0.0,
            iterations: 0,
            iter_equiv: 0,
            macro_steps: 0,
            preemptions: 0,
            finished: 0,
            latency: LatencyStats::new(),
            per_client_latency: ClientSlab::new(),
            service: ServiceTracker::new(),
            auditor: HolisticCounters::new(HfParams::default()),
            peak_tps: cfg.gpu.peak_decode_tps(64, 512),
            util_timeline: Vec::new(),
            backlog_timeline: Vec::new(),
            backlog_scratch: Vec::new(),
            last_backlog: None,
            win_start: 0.0,
            win_busy_util: 0.0,
            busy_util_total: 0.0,
            total_output_tokens: 0,
            total_weighted: 0.0,
            last_batch_sig: 0,
            rework: std::collections::HashMap::new(),
            fp_scratch: ClientSlab::new(),
            fp_touched: Vec::new(),
            rec: Box::new(NullRecorder),
            last_guard_mode: None,
            guard_transitions: 0,
            done: false,
        }
    }

    /// Append an externally-routed arrival. Arrivals must be injected in
    /// non-decreasing arrival order (the cluster driver routes the trace
    /// in order), and before the engine's loop-top at or after the
    /// arrival time consumes the stream — the driver guarantees both by
    /// gating every step on the next unrouted arrival.
    pub fn inject(&mut self, req: Request) {
        debug_assert!(
            self.pending.last_arrival().map_or(true, |a| a <= req.arrival),
            "inject out of arrival order"
        );
        self.pending.push(req);
    }

    /// Attach a flight recorder (replacing the default [`NullRecorder`]).
    /// Call before the first step so the trace covers the whole run.
    pub fn set_recorder(&mut self, rec: Box<dyn Recorder>) {
        self.rec = rec;
    }

    /// The attached recorder — the cluster driver drains its ring at
    /// barrier boundaries through this.
    pub fn recorder_mut(&mut self) -> &mut dyn Recorder {
        &mut *self.rec
    }

    /// Ring-overflow drops of the attached recorder (0 for the null one).
    pub fn recorder_dropped(&self) -> u64 {
        self.rec.dropped()
    }

    /// Current engine clock (end of the last completed iteration).
    pub fn time(&self) -> f64 {
        self.t
    }

    /// Terminal — see the `done` field.
    pub fn is_done(&self) -> bool {
        self.done
    }

    pub fn finished(&self) -> usize {
        self.finished
    }

    /// Requests seeded/injected so far (`total_requests` of the result).
    pub fn injected(&self) -> usize {
        self.pending.len()
    }

    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    /// An injected/seeded arrival has not yet been consumed by the loop.
    pub fn has_pending_arrival(&self) -> bool {
        self.next_arrival < self.pending.len()
    }

    pub fn kv_free_tokens(&self) -> u64 {
        self.kv.free_tokens()
    }

    pub fn kv_total_tokens(&self) -> u64 {
        self.kv.config().total_tokens()
    }

    /// Weighted-token service delivered so far, all clients — the cluster
    /// router's cheap load signal (routed-estimate minus delivered).
    pub fn delivered_weighted(&self) -> f64 {
        self.service.grand_total()
    }

    /// Force every running sequence back onto its scheduler queue (the
    /// replica-failure path): KV pages released, decode progress folded
    /// into the rework watermark exactly like a memory preemption — but
    /// NOT counted in `preemptions`, which tracks scheduling-pressure
    /// evictions only. Deterministic: slots evict in batch order.
    pub fn preempt_all_into(&mut self, scheduler: &mut dyn Scheduler) {
        for slot in std::mem::take(&mut self.running) {
            self.kv.release(slot.req.id).ok();
            let mut req = slot.req;
            let wm = self.rework.entry(req.id).or_insert(0);
            *wm = (*wm).max(req.generated);
            req.generated = 0;
            req.first_token_at = None;
            req.state = RequestState::Queued;
            scheduler.requeue(req);
        }
    }

    /// Remove every not-yet-finished request from this run as migration
    /// orphans. `queued` is the scheduler's charge-free drain — call
    /// [`RunState::preempt_all_into`] first so it includes the formerly
    /// running sequences — and the un-consumed tail of the arrival
    /// stream follows it. Finished requests stay behind: each request is
    /// counted at exactly one replica (its final home), so cluster-wide
    /// totals and conservation-modulo-shed sum cleanly.
    pub fn take_orphans(&mut self, queued: Vec<Request>) -> Vec<Orphan> {
        let mut orphans = Vec::with_capacity(queued.len());
        let mut ids = std::collections::HashSet::with_capacity(queued.len());
        for mut req in queued {
            ids.insert(req.id);
            req.generated = 0;
            req.first_token_at = None;
            req.state = RequestState::Queued;
            let rework = self.rework.remove(&req.id).unwrap_or(0);
            orphans.push(Orphan { req, rework });
        }
        let consumed = self.next_arrival;
        let mut kept = Vec::with_capacity(self.pending.len());
        for (i, req) in self.pending.drain_owned().into_iter().enumerate() {
            if i >= consumed {
                // Routed here but never consumed by the loop: migrates
                // whole, no progress to carry.
                let rework = self.rework.remove(&req.id).unwrap_or(0);
                orphans.push(Orphan { req, rework });
            } else if ids.contains(&req.id) {
                // Lives on as an orphan — drop the stale stream entry so
                // the destination's `total_requests` counts it instead.
            } else {
                kept.push(req);
            }
        }
        self.next_arrival = kept.len();
        self.pending.replace(kept);
        orphans
    }

    /// Re-home a migration orphan into this run's arrival stream. The
    /// request re-arrives at `now` (clamped up to the stream tail so the
    /// non-decreasing-arrival contract of [`RunState::inject`] holds):
    /// its end-to-end latency restarts from the migration instant — the
    /// failed attempt's wait is deliberately not carried, mirroring a
    /// client-side retry. A non-zero watermark installs as rework, so
    /// the destination re-decodes those tokens without re-crediting
    /// service or prefill.
    pub fn inject_migrated(&mut self, mut req: Request, rework: u32, now: f64) {
        let tail = self.pending.last_arrival().unwrap_or(f64::NEG_INFINITY);
        req.arrival = req.arrival.max(now).max(tail);
        req.generated = 0;
        req.first_token_at = None;
        req.finished_at = None;
        req.state = RequestState::Queued;
        if rework > 0 {
            self.rework.insert(req.id, rework);
        }
        self.pending.push(req);
    }

    /// Jump an idle clock forward (replica recovery at `t`): stepping
    /// resumes from the recovery instant. Never moves time backwards and
    /// touches no other state — the catch-up timeline windows emitted by
    /// the next step read zero utilization, which is exactly what a down
    /// replica did over the outage.
    pub fn fast_forward(&mut self, t: f64) {
        if t > self.t {
            self.t = t;
        }
    }

    /// Withhold KV pages from allocation (`KvShrink` fault injection) —
    /// pass-through to [`crate::kv::KvCache::set_reserved_pages`].
    pub fn kv_set_reserved_pages(&mut self, pages: u32) {
        self.kv.set_reserved_pages(pages);
    }

    /// Finalise into a `SimResult` (consumes the state).
    pub fn into_result(self, scheduler: &str) -> SimResult {
        let wall = self.t.max(1e-9);
        SimResult {
            scheduler: scheduler.to_string(),
            latency: self.latency,
            per_client_latency: self.per_client_latency,
            service: self.service,
            util_timeline: self.util_timeline,
            output_tps: self.total_output_tokens as f64 / wall,
            weighted_tps: self.total_weighted / wall,
            // SM-busy seconds over wall time — what nvidia-smi-style
            // monitoring (and the paper's Fig 9b/17b) reports.
            gpu_util: (self.busy_util_total / wall).min(1.0),
            finished: self.finished,
            total_requests: self.pending.len(),
            preemptions: self.preemptions,
            iterations: self.iterations,
            iter_equiv: self.iter_equiv,
            macro_steps: self.macro_steps,
            rework_live: self.rework.len(),
            guard_transitions: self.guard_transitions,
            final_hf: self.auditor.all_hf(),
            backlog_timeline: self.backlog_timeline,
            wall,
        }
    }
}

/// One engine loop iteration (a macro-step counts one) — the resumable
/// form of `Simulation::run`'s loop body, bit-for-bit. Returns `false`
/// when the run cannot proceed: terminal (`RunState::is_done`) or
/// drained-idle (revivable by [`RunState::inject`]). `external_arrival`
/// is the wall-clock time of the next arrival the driver has not yet
/// routed/injected: it bounds the event horizon and idle jumps exactly
/// as a queued arrival would, so a 1-replica cluster run is bit-identical
/// to the plain single-engine run.
pub fn step_once(
    cfg: &SimConfig,
    scheduler: &mut dyn Scheduler,
    predictor: &mut dyn Predictor,
    perfmap: &mut PerfMap,
    st: &mut RunState,
    external_arrival: Option<f64>,
) -> bool {
    if st.done {
        return false;
    }
    st.iterations += 1;
    if st.iterations > cfg.max_iterations {
        st.done = true;
        return false;
    }
    // Hoisted once per step: per-token / per-window capture below is
    // branch-gated on this local so a NullRecorder run pays nothing on
    // the token path (the allocation budget in tests/scale.rs holds).
    let rec_on = st.rec.enabled();

    // ---- arrivals ----
    loop {
        let Some(head) = st.pending.get(st.next_arrival) else { break };
        if head.arrival > st.t {
            break;
        }
        let mut req = head.clone();
        st.next_arrival += 1;
        predict_request(predictor, perfmap, &mut req);
        st.auditor.touch(req.client, 1.0);
        req.state = RequestState::Queued;
        st.rec.record(req.arrival, EventKind::Arrive { client: req.client, req: req.id });
        scheduler.enqueue(req, st.t);
    }

    let mut admitted_this_iter = 0u32;
    // ---- admission (Algorithm 1 lines 10–16) ----
    // Stall-free scheduling (§4): prediction-driven schedulers
    // reserve prompt + predicted output, but only once the cache
    // is under pressure — below the threshold, reservations would
    // just throttle admission for no benefit.
    let uses_pred = scheduler.uses_predictions();
    let total_tokens = st.kv.config().total_tokens().max(1);
    loop {
        if st.running.len() >= cfg.host.max_batch {
            break;
        }
        let free_tokens = st.kv.free_tokens();
        let pressure = 1.0 - free_tokens as f64 / total_tokens as f64;
        // Reservation fraction ramps with pressure: nothing below
        // 50% occupancy, the full predicted output as the pool
        // nears exhaustion. An all-or-nothing reserve would
        // throttle admission (and TTFT) long before preemption
        // was actually a risk.
        let reserve_frac = if uses_pred { ((pressure - 0.5) / 0.4).clamp(0.0, 1.0) } else { 0.0 };
        // vLLM-style watermark: keep enough headroom for the
        // resident batch to decode a window of steps, so admission
        // itself cannot trigger immediate preemption.
        let headroom = 32 * st.running.len() as u64;
        let picked = scheduler.pick(st.t, &mut |r: &Request| {
            let need = r.input_tokens as u64
                + (reserve_frac * r.predicted_output_tokens as f64) as u64
                + 16;
            need + headroom <= free_tokens
        });
        match picked {
            None => break,
            Some(mut req) => {
                let reserve = req.input_tokens
                    + (reserve_frac * req.predicted_output_tokens as f64) as u32;
                st.kv.allocate(req.id, reserve).expect("feasibility checked");
                req.state = RequestState::Prefilling;
                admitted_this_iter += 1;
                if rec_on {
                    // Pick decision: the chosen client's fairness score
                    // plus the best (lowest) losing score among queued
                    // rivals. Two passes because `for_each_queued_client`
                    // holds the scheduler borrow; the scratch vec is the
                    // hoisted backlog buffer, so no allocation once grown.
                    st.backlog_scratch.clear();
                    let scratch = &mut st.backlog_scratch;
                    scheduler.for_each_queued_client(&mut |c| scratch.push(c));
                    let chosen = scheduler.fairness_score(req.client).unwrap_or(0.0);
                    let mut rival = req.client;
                    let mut rival_score = f64::INFINITY;
                    let mut rivals = 0u32;
                    for &c in st.backlog_scratch.iter() {
                        if c == req.client {
                            continue;
                        }
                        rivals += 1;
                        let s = scheduler.fairness_score(c).unwrap_or(f64::INFINITY);
                        if s < rival_score {
                            rival_score = s;
                            rival = c;
                        }
                    }
                    if rivals == 0 || rival_score == f64::INFINITY {
                        rival = req.client;
                        rival_score = chosen;
                    }
                    st.rec.record(
                        st.t,
                        EventKind::Pick { client: req.client, score: chosen, rival, rival_score, rivals },
                    );
                    st.rec.record(
                        st.t,
                        EventKind::Admit {
                            client: req.client,
                            req: req.id,
                            queued: scheduler.queue_len() as u32,
                        },
                    );
                }
                st.running.push(Running {
                    kv_tokens: reserve,
                    admitted_at: st.t,
                    prefill_done: 0,
                    util_acc: 0.0,
                    util_time: 0.0,
                    req,
                });
            }
        }
    }

    // ---- idle fast-forward ----
    if st.running.is_empty() {
        let internal = st.pending.get(st.next_arrival).map(|r| r.arrival);
        // An unrouted cluster arrival is exactly as real as a queued one;
        // with no driver (plain run) `external_arrival` is None and this
        // folds to the seeded stream alone.
        let next_arr = match (internal, external_arrival) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, None) => a,
            (None, b) => b,
        };
        if scheduler.is_empty() && next_arr.is_none() {
            return false; // drained (revivable by a later inject)
        }
        let target = if scheduler.is_empty() {
            st.t.max(next_arr.unwrap())
        } else {
            // Queued but nothing admissible (e.g. RPM quota
            // exhaustion): advance straight to the next
            // admissibility event — the scheduler's own refresh
            // hint or the next arrival, whichever is sooner — so
            // idle periods cost O(1) iterations instead of a
            // fixed-constant spin. The 0.25 s probe survives only
            // as the fallback for a permanently infeasible head
            // with no pending arrivals (terminated by
            // `max_iterations`, or by the horizon when draining
            // is off).
            let refresh = scheduler.next_refresh_at(st.t).filter(|&r| r > st.t);
            match (next_arr, refresh) {
                (Some(a), Some(r)) => st.t.max(a.min(r)),
                (Some(a), None) => st.t.max(a),
                (None, Some(r)) => r,
                (None, None) => st.t + 0.25,
            }
        };
        // With draining off the idle jump must not carry the run
        // past the horizon (these `continue` paths bypass the
        // loop-bottom check).
        if !cfg.drain && target >= st.horizon {
            st.t = st.t.max(st.horizon);
            st.done = true;
            return false;
        }
        st.t = target;
        st.iter_equiv += 1;
        return true;
    }

    let any_prefill = st.running.iter().any(|r| r.prefill_done < r.req.input_tokens);
    let decode_allowed =
        cfg.host.mixed_batches || scheduler.system_optimizations() || !any_prefill;

    // ---- memory assurance before decode (vLLM recompute-style
    // preemption): if the batch's growth this step cannot be
    // backed by free pages, preempt the most recently admitted
    // sequences until it can. Their progress is lost and they
    // requeue — the cost prediction-blind schedulers pay under
    // pressure, which stall-free reservations avoid.
    if decode_allowed {
        loop {
            let mut needed_pages = 0u32;
            for r in st.running.iter() {
                if r.prefill_done >= r.req.input_tokens
                    && r.req.generated < r.req.true_output_tokens
                {
                    let ctx_after = r.req.input_tokens + r.req.generated + 1;
                    if ctx_after > r.kv_tokens && r.kv_tokens % 16 == 0 {
                        needed_pages += 1;
                    }
                }
            }
            if needed_pages <= st.kv.free_pages() || st.running.len() <= 1 {
                break;
            }
            // Victim: the newest-admitted sequence of the client
            // holding the largest resident KV footprint. Naive
            // newest-first would systematically churn the tenant
            // with the highest admission rate (usually the small-
            // request one), wrecking fairness for every policy.
            // Footprints accumulate in the hoisted scratch slab
            // (reset sparsely below) — no per-decision allocation.
            debug_assert!(st.fp_touched.is_empty());
            for r in st.running.iter() {
                if !st.fp_scratch.contains(r.req.client) {
                    st.fp_touched.push(r.req.client);
                }
                *st.fp_scratch.or_default(r.req.client) += r.kv_tokens as u64;
            }
            // Ascending scan with a strictly-greater update keeps the
            // SMALLEST client id among equal-footprint maxima — the
            // same winner the old map's
            // `max_by(count.cmp.then(reversed id))` selected.
            let mut best: Option<(u64, ClientId)> = None;
            st.fp_scratch.for_each(&mut |c, &fp| {
                if best.map(|(bf, _)| fp > bf).unwrap_or(true) {
                    best = Some((fp, c));
                }
            });
            let hog = best.map(|(_, c)| c).unwrap();
            for &c in st.fp_touched.iter() {
                st.fp_scratch.take(c);
            }
            st.fp_touched.clear();
            let victim = st
                .running
                .iter()
                .enumerate()
                .filter(|(_, r)| r.req.client == hog)
                .max_by(|a, b| {
                    a.1.admitted_at
                        .partial_cmp(&b.1.admitted_at)
                        .unwrap()
                        .then(a.0.cmp(&b.0))
                })
                .map(|(i, _)| i)
                .unwrap();
            st.preemptions += 1;
            let slot = st.running.swap_remove(victim);
            st.kv.release(slot.req.id).ok();
            let kv_held = slot.kv_tokens as u64;
            let mut req = slot.req;
            let wm = st.rework.entry(req.id).or_insert(0);
            *wm = (*wm).max(req.generated);
            req.generated = 0;
            req.first_token_at = None;
            req.state = RequestState::Queued;
            st.rec.record(
                st.t,
                EventKind::Preempt { client: req.client, req: req.id, kv_tokens: kv_held },
            );
            st.rec.record(st.t, EventKind::Requeue { client: req.client, req: req.id });
            scheduler.requeue(req);
        }
    }

    // ---- build the iteration mix ----
    let mut mix = IterationMix::default();
    let mut chunks: Vec<(usize, u32)> = Vec::new();
    if any_prefill {
        // Equinox's chunked-prefill coordination caps the per-
        // iteration prefill work so decode latency stays smooth
        // (Sarathi-style); baselines use the stock host budget.
        let mut budget = if scheduler.system_optimizations() {
            cfg.host.prefill_chunk.min(2048)
        } else {
            cfg.host.prefill_chunk
        };
        for (i, r) in st.running.iter().enumerate() {
            if budget == 0 {
                break;
            }
            let remaining = r.req.input_tokens - r.prefill_done;
            if remaining == 0 {
                continue;
            }
            let chunk = remaining.min(budget);
            budget -= chunk;
            mix.prefill_tokens += chunk as u64;
            mix.prefill_context += r.prefill_done as u64;
            chunks.push((i, chunk));
        }
    }
    if decode_allowed {
        for r in st.running.iter() {
            if r.prefill_done >= r.req.input_tokens && r.req.generated < r.req.true_output_tokens
            {
                mix.decode_seqs += 1;
                mix.decode_context += (r.req.input_tokens + r.req.generated) as u64;
            }
        }
    }
    if mix.prefill_tokens == 0 && mix.decode_seqs == 0 {
        // Whole batch blocked on chunk budget exhaustion for
        // already-prefilled requests in unmixed hosts — force a
        // decode-only iteration.
        for r in st.running.iter() {
            if r.req.generated < r.req.true_output_tokens {
                mix.decode_seqs += 1;
                mix.decode_context += (r.req.input_tokens + r.req.generated) as u64;
            }
        }
        if mix.decode_seqs == 0 {
            st.done = true; // degenerate (all zero-output requests)
            return false;
        }
    }

    // ---- batch-composition refresh (shared by both step paths) ----
    let sig = batch_signature(&st.running);
    let refresh = if sig != st.last_batch_sig { cfg.host.batch_refresh } else { 0.0 };
    st.last_batch_sig = sig;

    // ---- event horizon ----
    // A decode-only batch where every sequence has already
    // emitted its first token is piecewise predictable: nothing
    // the scheduler could admit becomes feasible mid-window (KV
    // only fills; admissions were already refused this iteration)
    // and composition is fixed until the first event. Compute the
    // number of safe iterations `k` and advance them all at once.
    let stable_decode = cfg.step_mode == StepMode::Macro
        && !any_prefill
        && decode_allowed
        && mix.decode_seqs as usize == st.running.len()
        && st.running.iter().all(|r| r.req.generated >= 1);
    let mut k = 1u64;
    if stable_decode {
        // Event 1: earliest sequence completion.
        let k_complete = st
            .running
            .iter()
            .map(|r| (r.req.true_output_tokens - r.req.generated) as u64)
            .min()
            .unwrap_or(1);
        // Event 2: KV free-page exhaustion (the next preemption
        // risk point) — largest window whose total page demand
        // fits in the free pool, so no mid-window preemption or
        // stall is possible.
        k = kv_safe_k(
            &st.running,
            st.kv.config().page_size as u64,
            st.kv.free_pages() as u64,
            k_complete,
        );
        if k >= 2 {
            // Events 3–6: next arrival (queued OR unrouted-external),
            // sample-window boundary, scheduler quota refresh, trace
            // horizon (drain off). All are wall-clock targets: cap `k`
            // at the first iteration whose cumulative time crosses the
            // nearest one, exactly where the per-token loop would act.
            let mut bound = st.win_start + cfg.sample_dt;
            if let Some(r) = st.pending.get(st.next_arrival) {
                bound = bound.min(r.arrival);
            }
            if let Some(a) = external_arrival {
                bound = bound.min(a);
            }
            if !scheduler.is_empty() {
                if let Some(tr) = scheduler.next_refresh_at(st.t) {
                    if tr > st.t {
                        bound = bound.min(tr);
                    }
                }
            }
            if !cfg.drain {
                bound = bound.min(st.horizon);
            }
            let gap = bound - st.t;
            if gap > 0.0 {
                k = min_crossing_k(
                    |kk| refresh + cfg.gpu.iterations_bulk(&mix, kk).time / cfg.host.efficiency,
                    gap,
                    k,
                );
            } else {
                k = 1; // a boundary is already due: single-step it
            }
        }
        k = k.max(1);
    }

    let mut completed: Vec<usize> = Vec::new();
    let t_end;
    if k >= 2 {
        // ---- macro-step: advance every sequence k tokens ----
        st.macro_steps += 1;
        st.iter_equiv += k;
        let bulk = cfg.gpu.iterations_bulk(&mix, k);
        // Serving-stack efficiency stretches the busy period,
        // exactly as in the per-token path. No admissions
        // happened this iteration (a fresh admission implies
        // prefill or a first token, both of which force micro),
        // so there is no host CPU term.
        let busy = bulk.busy / cfg.host.efficiency;
        let iter_time = bulk.time / cfg.host.efficiency;
        t_end = st.t + iter_time + refresh;
        st.busy_util_total += busy;
        st.win_busy_util += busy;
        let t0_window = st.t;
        let nrun = st.running.len() as u32;
        for (i, r) in st.running.iter_mut().enumerate() {
            r.util_acc += busy;
            r.util_time += iter_time;
            let ctx_target = r.req.input_tokens + r.req.generated + k as u32;
            if ctx_target > r.kv_tokens {
                st.kv
                    .grow_bulk(r.req.id, ctx_target - r.kv_tokens)
                    .expect("event horizon is bounded by the free page pool");
                r.kv_tokens = ctx_target;
            }
            let g0 = r.req.generated;
            r.req.generated += k as u32;
            // Fresh (never-before-delivered) tokens in this
            // window: everything past the rework watermark.
            // Totals match the per-token path exactly; the ramp
            // spreads them across the part of the window after
            // the watermark is re-crossed (prorated by token
            // position), so in-window service stays within the
            // one-token band of the per-token staircase even on
            // post-preemption recompute windows.
            let wm = st.rework.get(&r.req.id).copied().unwrap_or(0);
            let fresh = r.req.generated.saturating_sub(g0.max(wm));
            if fresh > 0 {
                let stale_frac = (k as u32 - fresh) as f64 / k as f64;
                let t0 = t0_window + stale_frac * (t_end - t0_window);
                st.service.record_bulk(r.req.client, t0, t_end, 4.0 * fresh as f64);
            }
            // The scheduler is charged for ALL k tokens (rework
            // included) in one aggregate call — same total as k
            // per-token calls.
            scheduler.on_progress(r.req.client, 4.0 * k as f64);
            if rec_on {
                st.rec.record(
                    t_end,
                    EventKind::Progress { client: r.req.client, tokens: 4.0 * k as f64, running: nrun },
                );
            }
            if r.req.generated >= r.req.true_output_tokens {
                completed.push(i);
            }
        }
    } else {
        // ---- micro-step (the per-token reference semantics) ----
        st.iter_equiv += 1;
        let mut cost = cfg.gpu.iteration(&mix);
        // Serving-stack efficiency (host loop, adapters):
        // stretches the busy period.
        cost.time /= cfg.host.efficiency;
        // Serialized host CPU per admitted request (GIL-bound
        // frontends).
        let host_cpu = admitted_this_iter as f64 * cfg.host.request_overhead;
        t_end = st.t + cost.time + refresh + host_cpu;

        st.busy_util_total += cost.time * cost.util;
        st.win_busy_util += cost.time * cost.util;

        // ---- advance requests ----
        for (i, chunk) in chunks {
            st.running[i].prefill_done += chunk;
        }
        for i in 0..st.running.len() {
            let prefilled = st.running[i].prefill_done >= st.running[i].req.input_tokens;
            st.running[i].util_acc += cost.time * cost.util;
            st.running[i].util_time += cost.time;
            if !prefilled || !decode_allowed && any_prefill {
                continue;
            }
            if st.running[i].req.generated >= st.running[i].req.true_output_tokens {
                completed.push(i);
                continue;
            }
            // One decode token.
            let ctx_after = st.running[i].req.input_tokens + st.running[i].req.generated + 1;
            if ctx_after > st.running[i].kv_tokens {
                if st.kv.grow(st.running[i].req.id, ctx_after - st.running[i].kv_tokens).is_ok()
                {
                    st.running[i].kv_tokens = ctx_after;
                } else {
                    // Assured above except in single-request corner
                    // cases; skip this step (stall).
                    continue;
                }
            }
            st.running[i].req.generated += 1;
            let fresh = st
                .rework
                .get(&st.running[i].req.id)
                .map(|wm| st.running[i].req.generated > *wm)
                .unwrap_or(true);
            if st.running[i].req.first_token_at.is_none() {
                st.running[i].req.first_token_at = Some(t_end);
                st.running[i].req.state = RequestState::Decoding;
                if rec_on {
                    st.rec.record(
                        t_end,
                        EventKind::FirstToken {
                            client: st.running[i].req.client,
                            req: st.running[i].req.id,
                            ttft: t_end - st.running[i].req.arrival,
                        },
                    );
                }
                // Prefill service is rendered by first-token time:
                // credit the prompt tokens (weight 1 each) — once,
                // even across preemption re-runs.
                let first_run =
                    st.rework.get(&st.running[i].req.id).map(|wm| *wm == 0).unwrap_or(true);
                if first_run {
                    st.service.record(
                        st.running[i].req.client,
                        t_end,
                        st.running[i].req.input_tokens as f64,
                    );
                }
            }
            // Token-granular service accounting (weight 4 per output
            // token) — continuous curves, no completion-lump aliasing.
            // Recomputed (post-preemption) tokens are not re-credited
            // as user-visible service, but they ARE charged to the
            // scheduler's counters: the GPU work was consumed, and
            // leaving it unpriced lets a repeatedly-preempted tenant
            // keep min-counter priority while burning capacity on
            // rework (a starvation spiral).
            if fresh {
                st.service.record(st.running[i].req.client, t_end, 4.0);
            }
            scheduler.on_progress(st.running[i].req.client, 4.0);
            if st.running[i].req.generated >= st.running[i].req.true_output_tokens {
                completed.push(i);
            }
        }
    }

    st.t = t_end;

    completed.sort_unstable();
    for &i in completed.iter().rev() {
        let slot = st.running.swap_remove(i);
        // Completion.
        let mut req = slot.req;
        req.finished_at = Some(st.t);
        req.state = RequestState::Finished;
        st.finished += 1;
        let e2e = st.t - req.arrival;
        st.rec.record(
            st.t,
            EventKind::Finish {
                client: req.client,
                req: req.id,
                e2e,
                predicted: req.predicted_output_tokens,
                actual: req.generated,
            },
        );
        let exec = st.t - slot.admitted_at;
        let out = req.generated;
        st.total_output_tokens += out as u64;
        let weighted = req.input_tokens as f64 + 4.0 * out as f64;
        st.total_weighted += weighted;
        // Busy-time-weighted average utilization over the
        // residency (macro-steps accumulate both terms in O(1)).
        let avg_util =
            if slot.util_time > 0.0 { (slot.util_acc / slot.util_time).min(1.0) } else { 0.0 };
        let actual_tps = (req.input_tokens + out) as f64 / exec.max(1e-9);
        let actuals =
            Actuals { latency: exec, gpu_util: avg_util, tps: actual_tps, output_tokens: out };
        scheduler.on_complete(&req, &actuals, st.t);
        predictor.observe(&req, out);
        perfmap.observe(
            req.input_tokens,
            out,
            crate::predictor::perfmap::MappedMetrics {
                latency: exec,
                gpu_util: avg_util,
                tps: actual_tps,
            },
        );
        // Scheduler-independent HF auditor (actual metrics).
        {
            let mut audited = req.clone();
            audited.predicted_output_tokens = out;
            audited.predicted_latency = exec;
            audited.predicted_tps = actual_tps;
            audited.predicted_gpu_util = avg_util;
            st.auditor.update_ufc_on_admit(&audited, st.t.min(e2e + audited.arrival));
            st.auditor.update_rfc_on_admit(&audited, st.peak_tps);
        }
        st.latency.observe(&req);
        st.per_client_latency.or_default(req.client).observe(&req);
        st.kv.release(req.id).ok();
        // The request is done for good — drop its rework
        // watermark, or the map grows without bound over long
        // preemption-heavy runs.
        st.rework.remove(&req.id);
    }

    // ---- calibration-guard transition edge ----
    // Guard mode can only move on completions (observations feed the
    // ladder), so polling here catches every transition exactly once.
    if !completed.is_empty() {
        if let Some(mode) = scheduler.guard_mode() {
            let code = mode.code();
            match st.last_guard_mode {
                Some(prev) if prev != code => {
                    st.guard_transitions += 1;
                    let err =
                        scheduler.guard_health().map(|h| h.abs_err_ewma).unwrap_or(0.0);
                    st.rec.record(st.t, EventKind::GuardTransition { from: prev, to: code, err });
                    st.last_guard_mode = Some(code);
                }
                None => st.last_guard_mode = Some(code),
                _ => {}
            }
        }
    }

    // ---- timeline sampling ----
    while st.t - st.win_start >= cfg.sample_dt {
        let u = (st.win_busy_util / cfg.sample_dt).min(1.0);
        st.util_timeline.push((st.win_start + cfg.sample_dt, u));
        st.backlog_scratch.clear();
        let scratch = &mut st.backlog_scratch;
        scheduler.for_each_queued_client(&mut |c| scratch.push(c));
        let unchanged =
            st.last_backlog.as_ref().map(|prev| prev[..] == st.backlog_scratch[..]).unwrap_or(false);
        let set: Arc<[ClientId]> = if unchanged {
            Arc::clone(st.last_backlog.as_ref().unwrap())
        } else {
            let fresh: Arc<[ClientId]> = Arc::from(&st.backlog_scratch[..]);
            st.last_backlog = Some(Arc::clone(&fresh));
            fresh
        };
        st.backlog_timeline.push((st.win_start + cfg.sample_dt, set));
        if rec_on {
            // Per-window counter snapshot for every backlogged client —
            // the trace-side view of the bounded-discrepancy evidence.
            let tw = st.win_start + cfg.sample_dt;
            let (scratch, rec) = (&st.backlog_scratch, &mut st.rec);
            for &c in scratch.iter() {
                let score = scheduler.fairness_score(c).unwrap_or(0.0);
                rec.record(tw, EventKind::Window { client: c, score });
            }
        }
        st.win_busy_util = 0.0;
        st.win_start += cfg.sample_dt;
    }

    // ---- termination ----
    let drained = st.running.is_empty() && scheduler.is_empty();
    if st.next_arrival >= st.pending.len() && drained {
        return false; // drained (revivable by a later inject)
    }
    // With draining off, stop at the horizon regardless of
    // outstanding work (see SimConfig::drain). The seed required
    // `drained` here too, which made the flag a no-op — the
    // drained case already broke above.
    if !cfg.drain && st.t >= st.horizon {
        st.done = true;
        return false;
    }
    true
}

/// Drive a resumable run forward until its clock reaches `horizon`, it
/// quiesces (drained idle — revivable by [`RunState::inject`]), or it
/// terminates. The per-step semantics are exactly [`step_once`] under the
/// same `external_arrival` bound: the horizon only decides where to STOP
/// stepping (the first clock ≥ `horizon`), never how far one step
/// reaches, so a run advanced in arbitrary horizon slices is bit-identical
/// to one stepped straight through (pinned by
/// `horizon_sliced_advance_matches_straight_run`). This is the parallel
/// cluster driver's per-replica advance between barriers.
///
/// Returns `true` when the run stopped at the horizon and can continue,
/// `false` when it cannot proceed further (quiescent or terminal). Like
/// `step_once`, probing an already-quiescent run costs one engine
/// iteration against `max_iterations` — callers that track runnability
/// (the cluster driver) should gate on it first.
pub fn advance_until(
    cfg: &SimConfig,
    scheduler: &mut dyn Scheduler,
    predictor: &mut dyn Predictor,
    perfmap: &mut PerfMap,
    st: &mut RunState,
    horizon: f64,
    external_arrival: Option<f64>,
) -> bool {
    while !st.done && st.t < horizon {
        if !step_once(cfg, scheduler, predictor, perfmap, st, external_arrival) {
            return false;
        }
    }
    !st.done
}

/// Total new KV pages a decode batch claims over a `k`-iteration window:
/// each sequence grows to `max(kv_tokens, ctx + k)` tokens (reservations
/// absorb growth until the context catches up), paying a page at each
/// page-size boundary crossing — exactly what `k` per-token `grow` calls
/// would claim.
fn kv_pages_needed(running: &[Running], page_size: u64, k: u64) -> u64 {
    running
        .iter()
        .map(|r| {
            let ctx = (r.req.input_tokens + r.req.generated) as u64;
            let target = (ctx + k).max(r.kv_tokens as u64);
            target.div_ceil(page_size) - (r.kv_tokens as u64).div_ceil(page_size)
        })
        .sum()
}

/// Largest window `k ≤ k_max` whose total page demand fits in the free
/// pool — within it, the per-token engine could not preempt or stall, so
/// a macro-step is safe. Returns 0 when even one token would overdraw
/// (the single-request KV-corner stall; the caller falls back to a
/// per-token step, which stalls identically).
fn kv_safe_k(running: &[Running], page_size: u64, free_pages: u64, k_max: u64) -> u64 {
    if kv_pages_needed(running, page_size, k_max) <= free_pages {
        return k_max;
    }
    if kv_pages_needed(running, page_size, 1) > free_pages {
        return 0;
    }
    // Bisect the monotone demand curve: need(lo) ≤ free < need(hi).
    let (mut lo, mut hi) = (1u64, k_max);
    while hi - lo > 1 {
        let mid = lo + (hi - lo) / 2;
        if kv_pages_needed(running, page_size, mid) <= free_pages {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    lo
}

/// Smallest `k ∈ [1, k_max]` whose cumulative window time crosses `gap`
/// (`time_of` is monotone in `k`), or `k_max` if the whole window stays
/// short of it. Stopping at the first crossing lands the engine clock on
/// exactly the iteration boundary where the per-token loop would have
/// acted on the event.
fn min_crossing_k(mut time_of: impl FnMut(u64) -> f64, gap: f64, k_max: u64) -> u64 {
    if time_of(k_max) < gap {
        return k_max;
    }
    let (mut lo, mut hi) = (1u64, k_max); // invariant: time_of(hi) ≥ gap
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if time_of(mid) >= gap {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    hi
}

/// Order-insensitive batch-composition signature for refresh detection.
/// XOR of per-id mixes: commutative, so no sort or allocation on the
/// per-iteration hot path (§Perf iteration 3).
fn batch_signature(running: &[Running]) -> u64 {
    running
        .iter()
        .map(|r| {
            let mut z = r.req.id.0.wrapping_add(0x9E37_79B9_7F4A_7C15);
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        })
        .fold(0x6a09_e667_f3bc_c909u64, |acc, x| acc ^ x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::Oracle;
    use crate::sched::{EquinoxSched, Fcfs, Vtc};
    use crate::workload::{generate, Scenario};

    fn short_trace() -> Trace {
        generate(&Scenario::balanced_load(20.0), 42)
    }

    #[test]
    fn fcfs_completes_all_requests() {
        let trace = short_trace();
        let mut sched = Fcfs::new();
        let mut pred = Oracle::new();
        let mut sim = Simulation::new(SimConfig::a100_7b_vllm(), &mut sched, &mut pred);
        let res = sim.run(&trace);
        assert_eq!(res.finished, trace.len(), "all requests must finish");
        assert!(res.wall > 0.0);
        assert!(res.output_tps > 0.0);
    }

    #[test]
    fn equinox_completes_all_requests() {
        let trace = short_trace();
        let mut sched = EquinoxSched::default_params(3000.0);
        let mut pred = Oracle::new();
        let mut sim = Simulation::new(SimConfig::a100_7b_vllm(), &mut sched, &mut pred);
        let res = sim.run(&trace);
        assert_eq!(res.finished, trace.len());
        assert_eq!(res.preemptions, 0, "oracle reservations must avoid preemption");
    }

    #[test]
    fn vtc_completes_all_requests() {
        let trace = short_trace();
        let mut sched = Vtc::new();
        let mut pred = Oracle::new();
        let mut sim = Simulation::new(SimConfig::a100_7b_vllm(), &mut sched, &mut pred);
        let res = sim.run(&trace);
        assert_eq!(res.finished, trace.len());
    }

    #[test]
    fn latencies_are_positive_and_ordered() {
        let trace = short_trace();
        let mut sched = Fcfs::new();
        let mut pred = Oracle::new();
        let mut sim = Simulation::new(SimConfig::a100_7b_vllm(), &mut sched, &mut pred);
        let res = sim.run(&trace);
        assert!(res.latency.ttft_mean() > 0.0);
        assert!(res.latency.e2e_mean() > res.latency.ttft_mean());
    }

    #[test]
    fn service_totals_match_token_accounting() {
        let trace = short_trace();
        let expected: f64 = trace.requests.iter().map(|r| r.weighted_tokens()).sum();
        let mut sched = Fcfs::new();
        let mut pred = Oracle::new();
        let mut sim = Simulation::new(SimConfig::a100_7b_vllm(), &mut sched, &mut pred);
        let res = sim.run(&trace);
        let total = res.service.grand_total();
        assert!((total - expected).abs() / expected < 1e-9, "total={total} expected={expected}");
    }

    #[test]
    fn util_timeline_is_bounded() {
        let trace = short_trace();
        let mut sched = Fcfs::new();
        let mut pred = Oracle::new();
        let mut sim = Simulation::new(SimConfig::a100_7b_vllm(), &mut sched, &mut pred);
        let res = sim.run(&trace);
        assert!(!res.util_timeline.is_empty());
        for (_, u) in &res.util_timeline {
            assert!((0.0..=1.0).contains(u));
        }
    }

    #[test]
    fn backlog_sets_are_interned() {
        let trace = short_trace();
        let mut sched = Fcfs::new();
        let mut pred = Oracle::new();
        let mut sim = Simulation::new(SimConfig::a100_7b_vllm(), &mut sched, &mut pred);
        let res = sim.run(&trace);
        assert!(!res.backlog_timeline.is_empty());
        for w in res.backlog_timeline.windows(2) {
            if w[0].1[..] == w[1].1[..] {
                assert!(
                    Arc::ptr_eq(&w[0].1, &w[1].1),
                    "consecutive identical backlog sets must share one allocation"
                );
            }
        }
    }

    #[test]
    fn macro_stepping_cuts_loop_iterations() {
        let trace = short_trace();
        let run = |mode: StepMode| {
            let mut sched = Fcfs::new();
            let mut pred = Oracle::new();
            let mut sim = Simulation::new(
                SimConfig::a100_7b_vllm().with_step_mode(mode),
                &mut sched,
                &mut pred,
            );
            sim.run(&trace)
        };
        let micro = run(StepMode::Micro);
        let mac = run(StepMode::Macro);
        assert_eq!(micro.iterations, micro.iter_equiv, "micro mode: 1 token per iteration");
        assert_eq!(micro.macro_steps, 0);
        assert!(mac.macro_steps > 0, "macro mode must take macro-steps on decode phases");
        assert!(
            mac.iterations < micro.iterations,
            "macro {} must beat micro {}",
            mac.iterations,
            micro.iterations
        );
        // Same token work was performed, just in fewer loop iterations.
        assert_eq!(mac.finished, micro.finished);
        assert_eq!(mac.iter_equiv, micro.iter_equiv);
    }

    #[test]
    fn rework_watermarks_drain_with_completions() {
        // Preemption-heavy setup: prediction-blind VTC on the memory-
        // constrained S-LoRA profile under constant overload. Every
        // completion must drop its rework entry — the seed leaked them
        // for the life of the run.
        let trace = generate(&Scenario::constant_overload(20.0), 5);
        let mut sched = Vtc::new();
        let mut pred = Oracle::new();
        // Shrink the KV pool so decode growth must overdraw it.
        let mut host = crate::sim::HostProfile::SLORA;
        host.kv_fraction = 0.08;
        let cfg = SimConfig::a100_7b_vllm().with_host(host);
        let mut sim = Simulation::new(cfg, &mut sched, &mut pred);
        let res = sim.run(&trace);
        assert_eq!(res.finished, trace.len());
        assert!(res.preemptions > 0, "setup must actually preempt to exercise the map");
        assert_eq!(res.rework_live, 0, "completed requests must leave no rework watermark");
    }

    #[test]
    fn no_drain_stops_at_horizon_with_work_outstanding() {
        // Overloaded trace: queues can never drain, so with drain off the
        // run must still terminate at the horizon (the seed's check also
        // required empty queues, making the flag a no-op).
        let trace = generate(&Scenario::constant_overload(15.0), 9);
        let mut cfg = SimConfig::a100_7b_vllm().with_host(crate::sim::HostProfile::SLORA);
        cfg.drain = false;
        let mut sched = Fcfs::new();
        let mut pred = Oracle::new();
        let mut sim = Simulation::new(cfg, &mut sched, &mut pred);
        let res = sim.run(&trace);
        assert!(res.wall >= trace.horizon, "must reach the horizon");
        assert!(res.wall < trace.horizon + 5.0, "must stop promptly after the horizon");
        assert!(
            res.finished < res.total_requests,
            "overload means work was outstanding at the horizon"
        );
    }

    #[test]
    fn backlog_introspection_matches_timeline() {
        // Overloaded trace: both clients stay backlogged, so the merged
        // intervals and the pairwise discrepancy series must be non-empty
        // and consistent with the raw timeline.
        let trace = generate(&Scenario::constant_overload(15.0), 3);
        let mut sched = Vtc::new();
        let mut pred = Oracle::new();
        let cfg = SimConfig::a100_7b_vllm().with_host(crate::sim::HostProfile::SLORA);
        let mut sim = Simulation::new(cfg, &mut sched, &mut pred);
        let res = sim.run(&trace);
        let ever = res.ever_backlogged_clients();
        assert!(ever.contains(&ClientId(0)) && ever.contains(&ClientId(1)), "{ever:?}");
        for c in ever {
            let ivs = res.backlogged_intervals(c);
            assert!(!ivs.is_empty(), "{c} was backlogged but has no interval");
            for (s, e) in &ivs {
                assert!(s <= e);
                // Every sample inside a reported interval contains c.
                for (t, set) in &res.backlog_timeline {
                    if t >= s && t <= e {
                        assert!(set.contains(&c), "{c} missing at t={t} in [{s},{e}]");
                    }
                }
            }
        }
        // Two-client run: the all-pairs max equals the single-pair max.
        let pair_max = res
            .backlogged_diff_series(ClientId(0), ClientId(1))
            .iter()
            .cloned()
            .fold(0.0, f64::max);
        assert_eq!(res.max_co_backlogged_diff(), pair_max);
        assert!(pair_max > 0.0, "overload must produce a co-backlogged gap");
    }

    /// The resumable stepper driven the way the cluster driver drives it
    /// — start_empty, online inject gated on the next unrouted arrival,
    /// external-arrival bounds — must reproduce the plain seeded run
    /// bit-for-bit (the 1-replica zero-drift contract).
    #[test]
    fn stepwise_injection_matches_seeded_run() {
        let trace = short_trace();
        let cfg = SimConfig::a100_7b_vllm();
        let plain = {
            let mut sched = Vtc::new();
            let mut pred = Oracle::new();
            let mut sim = Simulation::new(cfg.clone(), &mut sched, &mut pred);
            sim.run(&trace)
        };

        let mut sched = Vtc::new();
        let mut pred = Oracle::new();
        let mut pm = crate::predictor::PerfMap::default_a100_7b();
        let mut st = RunState::start_empty(&cfg, trace.horizon);
        let mut next = 0usize;
        loop {
            let gate = trace.requests.get(next).map(|r| r.arrival);
            loop {
                let runnable = !st.is_done()
                    && (st.running_len() > 0 || !sched.is_empty() || st.has_pending_arrival());
                if !runnable {
                    break;
                }
                if let Some(g) = gate {
                    if st.time() >= g {
                        break;
                    }
                }
                if !step_once(&cfg, &mut sched, &mut pred, &mut pm, &mut st, gate) {
                    break;
                }
            }
            match trace.requests.get(next) {
                None => break,
                Some(r) => {
                    st.inject(r.clone());
                    next += 1;
                }
            }
        }
        let stepped = st.into_result("vtc");

        assert_eq!(stepped.finished, plain.finished);
        assert_eq!(stepped.total_requests, plain.total_requests);
        assert_eq!(stepped.iterations, plain.iterations);
        assert_eq!(stepped.iter_equiv, plain.iter_equiv);
        assert_eq!(stepped.macro_steps, plain.macro_steps);
        assert_eq!(stepped.wall.to_bits(), plain.wall.to_bits());
        assert_eq!(stepped.output_tps.to_bits(), plain.output_tps.to_bits());
        assert_eq!(stepped.gpu_util.to_bits(), plain.gpu_util.to_bits());
        assert_eq!(stepped.service.clients(), plain.service.clients());
        for c in plain.service.clients() {
            assert_eq!(
                stepped.service.total(c).to_bits(),
                plain.service.total(c).to_bits(),
                "service[{c}] diverged"
            );
        }
    }

    /// The parallel cluster driver's foundational property: advancing a
    /// run in arbitrary horizon slices is bit-identical to stepping it
    /// straight through — the horizon decides where stepping PAUSES,
    /// never what a step does.
    #[test]
    fn horizon_sliced_advance_matches_straight_run() {
        let trace = short_trace();
        let cfg = SimConfig::a100_7b_vllm();
        let plain = {
            let mut sched = Vtc::new();
            let mut pred = Oracle::new();
            let mut sim = Simulation::new(cfg.clone(), &mut sched, &mut pred);
            sim.run(&trace)
        };

        let mut sched = Vtc::new();
        let mut pred = Oracle::new();
        let mut pm = crate::predictor::PerfMap::default_a100_7b();
        let mut st = RunState::start(&cfg, &trace);
        // Deliberately awkward slice width so horizons land mid-window,
        // mid-decode, and mid-drain.
        let mut h = 0.7;
        while advance_until(&cfg, &mut sched, &mut pred, &mut pm, &mut st, h, None) {
            h += 0.7;
        }
        let sliced = st.into_result("vtc");

        assert_eq!(sliced.finished, plain.finished);
        assert_eq!(sliced.iterations, plain.iterations);
        assert_eq!(sliced.iter_equiv, plain.iter_equiv);
        assert_eq!(sliced.macro_steps, plain.macro_steps);
        assert_eq!(sliced.preemptions, plain.preemptions);
        assert_eq!(sliced.wall.to_bits(), plain.wall.to_bits());
        assert_eq!(sliced.output_tps.to_bits(), plain.output_tps.to_bits());
        assert_eq!(sliced.service.clients(), plain.service.clients());
        for c in plain.service.clients() {
            assert_eq!(
                sliced.service.total(c).to_bits(),
                plain.service.total(c).to_bits(),
                "service[{c}] diverged"
            );
        }
    }

    /// The migration cycle: extract orphans from a half-finished run,
    /// re-home them in a fresh engine, and the pair together delivers
    /// exactly the trace's demand — each request finished once, counted
    /// once, service credited once (re-decoded tokens gated by the
    /// rework watermark).
    #[test]
    fn orphan_migration_conserves_service_and_counts() {
        let trace = short_trace();
        let cfg = SimConfig::a100_7b_vllm();
        let mut sched_a = Vtc::new();
        let mut pred_a = Oracle::new();
        let mut pm_a = crate::predictor::PerfMap::default_a100_7b();
        let mut a = RunState::start(&cfg, &trace);
        // Step A until it has finished something but plenty remains.
        while a.finished() == 0 {
            assert!(step_once(&cfg, &mut sched_a, &mut pred_a, &mut pm_a, &mut a, None));
        }
        let t_fail = a.time();
        a.preempt_all_into(&mut sched_a);
        let queued = sched_a.drain_queued();
        let orphans = a.take_orphans(queued);
        assert!(!orphans.is_empty(), "mid-run failure must orphan outstanding work");
        assert!(orphans.iter().any(|o| o.rework > 0), "some orphan was mid-decode");
        assert_eq!(a.running_len(), 0);
        assert!(sched_a.is_empty());
        // Destination picks the orphans up at the failure instant.
        let mut sched_b = Vtc::new();
        let mut pred_b = Oracle::new();
        let mut pm_b = crate::predictor::PerfMap::default_a100_7b();
        let mut b = RunState::start_empty(&cfg, trace.horizon);
        b.fast_forward(t_fail);
        let n_orphans = orphans.len();
        for o in orphans {
            b.inject_migrated(o.req, o.rework, t_fail);
        }
        while step_once(&cfg, &mut sched_b, &mut pred_b, &mut pm_b, &mut b, None) {}
        let ra = a.into_result("vtc");
        let rb = b.into_result("vtc");
        assert_eq!(rb.finished, n_orphans, "every orphan must finish at the destination");
        assert_eq!(ra.finished + rb.finished, trace.len());
        assert_eq!(ra.total_requests + rb.total_requests, trace.len());
        assert_eq!(rb.rework_live, 0, "watermarks must drain with completions");
        let expected: f64 = trace.requests.iter().map(|r| r.weighted_tokens()).sum();
        let total = ra.service.grand_total() + rb.service.grand_total();
        assert!(
            (total - expected).abs() / expected < 1e-9,
            "service across the pair: total={total} expected={expected}"
        );
    }

    #[test]
    fn deterministic_given_seeded_inputs() {
        let trace = short_trace();
        let run = || {
            let mut sched = EquinoxSched::default_params(3000.0);
            let mut pred = Oracle::new();
            let mut sim =
                Simulation::new(SimConfig::a100_7b_vllm(), &mut sched, &mut pred);
            let r = sim.run(&trace);
            (r.finished, r.iterations, r.output_tps)
        };
        assert_eq!(run(), run());
    }
}
