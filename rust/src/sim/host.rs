//! Host-system profiles: the serving-stack parameters that differ between
//! the paper's three integration targets (S-LoRA, vLLM, SGLang). Fig 13 /
//! Fig 16 show Equinox's properties hold across all three; the profiles
//! vary exactly the knobs those systems differ on — batch caps, chunked-
//! prefill budgets, and per-refresh host overhead.

/// Serving-host parameters consumed by the engine.
#[derive(Debug, Clone, Copy)]
pub struct HostProfile {
    pub name: &'static str,
    /// Max concurrent sequences in the running batch.
    pub max_batch: usize,
    /// Chunked-prefill token budget per iteration (Sarathi-style); the
    /// engine splits prompts into chunks of at most this size and shares
    /// the budget across prefilling requests.
    pub prefill_chunk: u32,
    /// Host-side cost of re-forming the batch when composition changes
    /// (scheduling, tokenizer hand-off, CUDA-graph rebuild...). This is
    /// the CPU-bound gap behind Fig 2c's utilization steps.
    pub batch_refresh: f64,
    /// Whether decode iterations can run concurrently with prefill chunks
    /// in one iteration (piggyback batching).
    pub mixed_batches: bool,
    /// Delivered fraction of the roofline iteration rate — serving-stack
    /// overhead (Python host loop, adapter switching, tokenizer hand-off).
    /// S-LoRA's adapter juggling makes it markedly slower than vLLM.
    pub efficiency: f64,
    /// Fraction of the GPU's KV budget actually available to the cache
    /// (S-LoRA parks LoRA adapters in the same unified pool).
    pub kv_fraction: f64,
    /// Serialized host-CPU cost per admitted request (tokenisation,
    /// sampling-state setup, detokenisation, HTTP). Python host loops cap
    /// at tens of requests/s — the per-request ceiling behind Fig 2b's
    /// throughput *rise* with request size.
    pub request_overhead: f64,
}

impl HostProfile {
    /// vLLM-like: big batches, PagedAttention, chunked prefill on, modest
    /// refresh cost.
    pub const VLLM: HostProfile = HostProfile {
        name: "vllm",
        max_batch: 256,
        prefill_chunk: 2048,
        batch_refresh: 0.004,
        mixed_batches: true,
        efficiency: 1.0,
        kv_fraction: 0.85,
        request_overhead: 0.008,
    };

    /// SGLang-like: RadixAttention scheduling keeps refresh cheap, large
    /// token budget.
    pub const SGLANG: HostProfile = HostProfile {
        name: "sglang",
        max_batch: 256,
        prefill_chunk: 4096,
        batch_refresh: 0.003,
        mixed_batches: true,
        efficiency: 1.05,
        kv_fraction: 0.85,
        request_overhead: 0.006,
    };

    /// S-LoRA-like: adapter juggling raises refresh cost, smaller batches,
    /// no chunked prefill (whole prompts at once).
    pub const SLORA: HostProfile = HostProfile {
        name: "slora",
        max_batch: 64,
        prefill_chunk: 8192,
        batch_refresh: 0.008,
        mixed_batches: false,
        efficiency: 0.75,
        kv_fraction: 0.35,
        request_overhead: 0.020,
    };

    pub fn by_name(name: &str) -> Option<HostProfile> {
        match name {
            "vllm" => Some(Self::VLLM),
            "sglang" => Some(Self::SGLANG),
            "slora" | "s-lora" => Some(Self::SLORA),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_by_name() {
        assert_eq!(HostProfile::by_name("vllm").unwrap().name, "vllm");
        assert_eq!(HostProfile::by_name("s-lora").unwrap().name, "slora");
        assert!(HostProfile::by_name("triton").is_none());
    }

    #[test]
    fn profiles_differ_in_refresh_cost() {
        assert!(HostProfile::SLORA.batch_refresh > HostProfile::VLLM.batch_refresh);
        assert!(HostProfile::SGLANG.batch_refresh < HostProfile::VLLM.batch_refresh);
    }

    #[test]
    fn slora_is_slower_and_memory_constrained() {
        assert!(HostProfile::SLORA.efficiency < HostProfile::VLLM.efficiency);
        assert!(HostProfile::SLORA.kv_fraction < HostProfile::VLLM.kv_fraction);
    }
}
