//! Roofline GPU cost model (DESIGN.md substitution ledger, row 1).
//!
//! Iteration time = max(compute term, memory term) + kernel constant.
//! Prefill is compute-bound (parallel token processing against peak
//! matmul throughput); decode is memory-bound (weights + KV-cache reads
//! against HBM bandwidth) — the bifurcation of the paper's Fig 3. Tensor
//! parallelism divides both weights and KV across GPUs with an efficiency
//! discount for collectives.

/// Hardware profile of one accelerator.
#[derive(Debug, Clone, Copy)]
pub struct GpuKind {
    pub name: &'static str,
    /// Peak dense FP16/BF16 FLOP/s.
    pub peak_flops: f64,
    /// HBM bandwidth, bytes/s.
    pub mem_bw: f64,
    /// HBM capacity, bytes.
    pub mem_bytes: u64,
}

impl GpuKind {
    pub const A100_80G: GpuKind = GpuKind {
        name: "A100-80GB",
        peak_flops: 312e12,
        mem_bw: 2.039e12,
        mem_bytes: 80 * (1 << 30) as u64,
    };

    pub const A100_40G: GpuKind = GpuKind {
        name: "A100-40GB",
        peak_flops: 312e12,
        mem_bw: 1.555e12,
        mem_bytes: 40 * (1 << 30) as u64,
    };
}

/// Transformer shape — enough to price FLOPs and bytes.
#[derive(Debug, Clone, Copy)]
pub struct ModelSpec {
    pub name: &'static str,
    pub n_params: f64,
    pub n_layers: u32,
    pub d_model: u32,
    pub n_heads: u32,
    pub n_kv_heads: u32,
    pub head_dim: u32,
    /// Bytes per weight/KV element (2 for fp16/bf16).
    pub dtype_bytes: u32,
}

impl ModelSpec {
    pub const LLAMA2_7B: ModelSpec = ModelSpec {
        name: "llama-2-7b",
        n_params: 6.74e9,
        n_layers: 32,
        d_model: 4096,
        n_heads: 32,
        n_kv_heads: 32,
        head_dim: 128,
        dtype_bytes: 2,
    };

    pub const LLAMA2_70B: ModelSpec = ModelSpec {
        name: "llama-2-70b",
        n_params: 69e9,
        n_layers: 80,
        d_model: 8192,
        n_heads: 64,
        n_kv_heads: 8, // GQA
        head_dim: 128,
        dtype_bytes: 2,
    };

    /// KV bytes stored per token: K and V across all layers.
    pub fn kv_bytes_per_token(&self) -> u64 {
        2 * self.n_layers as u64
            * self.n_kv_heads as u64
            * self.head_dim as u64
            * self.dtype_bytes as u64
    }

    pub fn weight_bytes(&self) -> u64 {
        (self.n_params * self.dtype_bytes as f64) as u64
    }
}

/// The composed model: hardware × transformer × tensor parallelism.
#[derive(Debug, Clone, Copy)]
pub struct GpuModel {
    pub gpu: GpuKind,
    pub model: ModelSpec,
    pub tp: u32,
    /// Achievable fraction of peak FLOPs in prefill matmuls.
    pub mxu_eff: f64,
    /// Achievable fraction of HBM bandwidth in decode.
    pub bw_eff: f64,
    /// Fixed per-iteration kernel-launch/framework cost (s).
    pub kernel_const: f64,
}

/// Composition of one engine iteration.
#[derive(Debug, Clone, Copy, Default)]
pub struct IterationMix {
    /// Prompt tokens processed this iteration (chunked prefill sum).
    pub prefill_tokens: u64,
    /// Sum over prefilling requests of their existing context (attention
    /// against already-cached tokens).
    pub prefill_context: u64,
    /// Number of sequences taking one decode step.
    pub decode_seqs: u64,
    /// Sum of the context lengths of those sequences (KV read volume).
    pub decode_context: u64,
}

/// Aggregate cost of `k` successive decode iterations (see
/// [`GpuModel::iterations_bulk`]).
#[derive(Debug, Clone, Copy)]
pub struct BulkCost {
    /// Σ busy_j — SM-busy seconds across the window (max of the compute
    /// and memory terms, per iteration).
    pub busy: f64,
    /// Σ (busy_j + kernel_const) — total engine time for the window.
    pub time: f64,
    pub flops: f64,
    pub bytes: f64,
}

/// Cost breakdown of one iteration.
#[derive(Debug, Clone, Copy)]
pub struct IterationCost {
    pub time: f64,
    pub compute_time: f64,
    pub memory_time: f64,
    /// SM-busy fraction of the iteration (what nvidia-smi reports and the
    /// paper plots as "GPU utilization"): kernels are executing for the
    /// whole busy period; only the fixed launch/framework gap is idle.
    pub util: f64,
    /// Compute-unit (MXU/tensor-core) utilization — the roofline ratio,
    /// used for the §Kernel-roofline analysis, NOT the paper's util plots.
    pub mxu_util: f64,
    pub flops: f64,
    pub bytes: f64,
}

impl GpuModel {
    pub fn new(gpu: GpuKind, model: ModelSpec, tp: u32) -> Self {
        // bw_eff 0.60: measured serving stacks (paged KV gather, quantised
        // layouts) reach ~60% of peak HBM bandwidth in decode, not the
        // STREAM-style 80%; this calibrates aggregate decode throughput to
        // the ~1–2k tok/s the paper's Llama-2-7b/A100 testbed delivers.
        GpuModel { gpu, model, tp, mxu_eff: 0.52, bw_eff: 0.60, kernel_const: 0.003 }
    }

    pub fn a100_7b() -> Self {
        Self::new(GpuKind::A100_80G, ModelSpec::LLAMA2_7B, 1)
    }

    pub fn a100_70b_tp8() -> Self {
        Self::new(GpuKind::A100_40G, ModelSpec::LLAMA2_70B, 8)
    }

    /// Tensor-parallel collective efficiency: each doubling of TP costs a
    /// little (all-reduce latency), modelled as 6% per doubling.
    pub fn tp_eff(&self) -> f64 {
        0.94f64.powf((self.tp as f64).log2())
    }

    /// HBM left for KV after weights (per full replica across TP).
    pub fn kv_budget_bytes(&self) -> u64 {
        let total = self.gpu.mem_bytes as f64 * self.tp as f64;
        let weights = self.model.weight_bytes() as f64;
        // ~10% reserved for activations/workspace.
        ((total - weights) * 0.9).max(0.0) as u64
    }

    /// Max KV tokens resident (across the TP group).
    pub fn kv_token_capacity(&self) -> u64 {
        self.kv_budget_bytes() / self.model.kv_bytes_per_token().max(1)
    }

    /// FLOPs for processing `new_tokens` with `context` already cached:
    /// linear term 2·P per token plus attention 2·2·layers·(heads·head_dim)
    /// per (new token × context token) pair.
    fn flops(&self, new_tokens: u64, context_pairs: f64) -> f64 {
        let linear = 2.0 * self.model.n_params * new_tokens as f64;
        let attn = 4.0
            * self.model.n_layers as f64
            * (self.model.n_heads * self.model.head_dim) as f64
            * context_pairs;
        linear + attn
    }

    /// Cost one iteration of the continuous-batching engine.
    pub fn iteration(&self, mix: &IterationMix) -> IterationCost {
        let m = &self.model;
        // ---- compute term ----
        // Prefill attention pairs ≈ new·(ctx + new/2) per request; the
        // engine passes the summed products. Decode: 1 new token × ctx.
        let prefill_pairs = mix.prefill_tokens as f64 * mix.prefill_context as f64
            + 0.5 * (mix.prefill_tokens as f64).powi(2).min(mix.prefill_tokens as f64 * 4096.0);
        let decode_pairs = mix.decode_context as f64;
        let flops = self.flops(mix.prefill_tokens + mix.decode_seqs, prefill_pairs + decode_pairs);
        let peak = self.gpu.peak_flops * self.tp as f64 * self.mxu_eff * self.tp_eff();
        // Small batches can't saturate the MXU: scale efficiency by
        // occupancy (tokens in flight vs a saturation constant).
        let tokens_in_flight = (mix.prefill_tokens + mix.decode_seqs) as f64;
        let occupancy = (tokens_in_flight / 256.0).min(1.0).max(0.02);
        let compute_time = flops / (peak * (0.35 + 0.65 * occupancy));

        // ---- memory term ----
        // Weights stream once per iteration; KV reads for decode contexts
        // and prefill attention contexts; KV writes for all new tokens.
        let kv_b = m.kv_bytes_per_token() as f64;
        let bytes = m.weight_bytes() as f64
            + kv_b * (mix.decode_context as f64 + mix.prefill_context as f64)
            + kv_b * (mix.prefill_tokens + mix.decode_seqs) as f64;
        let bw = self.gpu.mem_bw * self.tp as f64 * self.bw_eff * self.tp_eff();
        let memory_time = bytes / bw;

        let busy = compute_time.max(memory_time);
        let time = busy + self.kernel_const;
        IterationCost {
            time,
            compute_time,
            memory_time,
            util: (busy / time).min(1.0),
            mxu_util: (compute_time / time).min(1.0),
            flops,
            bytes,
        }
    }

    /// Aggregate cost of `k` successive decode-only iterations: iteration
    /// `j` (0-based) prices `mix.decode_seqs` new tokens against total
    /// context `mix.decode_context + j·decode_seqs` — the arithmetic
    /// series a stable decode batch walks between scheduling events.
    ///
    /// Closed form over the context series (O(log k) for the compute/
    /// memory regime split, O(1) arithmetic otherwise) rather than `k`
    /// calls to [`GpuModel::iteration`]; the per-iteration compute and
    /// memory terms are evaluated with *identical* arithmetic to
    /// `iteration`, so the regime choice (which term dominates) matches
    /// the per-token engine bit-for-bit and the summed busy time agrees
    /// with the serial sum to float rounding (≪ 1e-9 relative). This is
    /// what makes event-horizon macro-stepping in `sim::engine` an exact
    /// performance transformation, not a model change.
    pub fn iterations_bulk(&self, mix: &IterationMix, k: u64) -> BulkCost {
        debug_assert!(
            mix.prefill_tokens == 0 && mix.prefill_context == 0,
            "bulk costing is decode-only"
        );
        debug_assert!(k >= 1 && mix.decode_seqs >= 1);
        let m = &self.model;
        let n = mix.decode_seqs as f64;
        let d0 = mix.decode_context as f64;

        // Per-iteration terms, mirroring `iteration`'s arithmetic exactly.
        let linear = 2.0 * m.n_params * n;
        let attn_per_pair = 4.0 * m.n_layers as f64 * (m.n_heads * m.head_dim) as f64;
        let peak = self.gpu.peak_flops * self.tp as f64 * self.mxu_eff * self.tp_eff();
        let occupancy = (n / 256.0).min(1.0).max(0.02);
        let denom = peak * (0.35 + 0.65 * occupancy);
        let wb = m.weight_bytes() as f64;
        let kv_b = m.kv_bytes_per_token() as f64;
        let bw = self.gpu.mem_bw * self.tp as f64 * self.bw_eff * self.tp_eff();
        let compute_at = |j: u64| (linear + attn_per_pair * (d0 + j as f64 * n)) / denom;
        let memory_at = |j: u64| (wb + kv_b * (d0 + j as f64 * n) + kv_b * n) / bw;

        // max(compute, memory) over a window of two linear functions: one
        // regime flip at most. Locate it by bisection on the *exact*
        // per-iteration comparison so the split matches a serial walk.
        let compute_first = compute_at(0) >= memory_at(0);
        let compute_last = compute_at(k - 1) >= memory_at(k - 1);
        let split = if compute_first == compute_last {
            k
        } else {
            let (mut lo, mut hi) = (0u64, k - 1);
            while hi - lo > 1 {
                let mid = lo + (hi - lo) / 2;
                if (compute_at(mid) >= memory_at(mid)) == compute_first {
                    lo = mid;
                } else {
                    hi = mid;
                }
            }
            hi
        };

        // Σ_{j=j0}^{j1-1} (v0 + step·j), exact arithmetic series.
        let arith_sum = |v0: f64, step: f64, j0: u64, j1: u64| -> f64 {
            if j1 <= j0 {
                return 0.0;
            }
            let cnt = (j1 - j0) as f64;
            let jsum = cnt * (j0 as f64 + (j1 - 1) as f64) / 2.0;
            v0 * cnt + step * jsum
        };
        let compute_sum =
            |j0, j1| arith_sum((linear + attn_per_pair * d0) / denom, attn_per_pair * n / denom, j0, j1);
        let memory_sum =
            |j0, j1| arith_sum((wb + kv_b * d0 + kv_b * n) / bw, kv_b * n / bw, j0, j1);
        let seg = |compute_regime: bool, j0: u64, j1: u64| {
            if compute_regime {
                compute_sum(j0, j1)
            } else {
                memory_sum(j0, j1)
            }
        };
        let busy = seg(compute_first, 0, split) + seg(!compute_first, split, k);
        BulkCost {
            busy,
            time: busy + k as f64 * self.kernel_const,
            flops: arith_sum(linear + attn_per_pair * d0, attn_per_pair * n, 0, k),
            bytes: arith_sum(wb + kv_b * d0 + kv_b * n, kv_b * n, 0, k),
        }
    }

    /// Convenience: a pure decode step for `batch` sequences with average
    /// context `ctx`.
    pub fn decode_step(&self, batch: u64, avg_ctx: u64) -> IterationCost {
        self.iteration(&IterationMix {
            decode_seqs: batch,
            decode_context: batch * avg_ctx,
            ..Default::default()
        })
    }

    /// Convenience: a pure prefill of `tokens` prompt tokens.
    pub fn prefill(&self, tokens: u64) -> IterationCost {
        self.iteration(&IterationMix { prefill_tokens: tokens, ..Default::default() })
    }

    /// Peak sustainable decode throughput (tokens/s) — used to normalise
    /// RFC's TPS term.
    pub fn peak_decode_tps(&self, batch: u64, avg_ctx: u64) -> f64 {
        let c = self.decode_step(batch, avg_ctx);
        batch as f64 / c.time
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefill_is_compute_bound_decode_memory_bound() {
        let g = GpuModel::a100_7b();
        let p = g.prefill(2048);
        assert!(p.compute_time > p.memory_time, "prefill must be compute-bound: {p:?}");
        let d = g.decode_step(8, 512);
        assert!(d.memory_time > d.compute_time, "decode must be memory-bound: {d:?}");
    }

    #[test]
    fn decode_dominates_e2e_latency() {
        // Fig 2a/§1: decode consumes >90% of end-to-end time for typical
        // shapes (1:1 in:out, e.g. 256 in / 256 out).
        let g = GpuModel::a100_7b();
        let prefill = g.prefill(256).time;
        let decode: f64 = (0..256).map(|i| g.decode_step(1, 256 + i).time).sum();
        let frac = decode / (decode + prefill);
        assert!(frac > 0.9, "decode fraction = {frac}");
    }

    #[test]
    fn latency_monotone_in_tokens() {
        let g = GpuModel::a100_7b();
        let mut prev = 0.0;
        for out in [32u64, 64, 128, 256, 512, 1024, 2048] {
            let e2e: f64 = g.prefill(out).time
                + (0..out).map(|i| g.decode_step(1, out + i).time).sum::<f64>();
            assert!(e2e > prev, "latency not monotone at {out}");
            prev = e2e;
        }
    }

    /// The two mechanisms behind Fig 2b's rise-then-fall throughput:
    /// (rise) short requests churn the batch — the refresh overhead per
    /// useful token falls with request length; (fall) KV reads per decode
    /// step grow with context, so per-token cost rises for long requests.
    /// The full curve is produced at the system level by `exp::fig2`.
    #[test]
    fn fig2b_mechanisms() {
        let g = GpuModel::a100_7b();
        let refresh = 0.004f64; // vLLM-profile batch refresh
        // Refresh cost per output token: one composition change per
        // completed request, amortised over its output tokens.
        let refresh_per_token = |out: u64| refresh / out as f64;
        assert!(refresh_per_token(32) > 10.0 * refresh_per_token(1024));
        // KV term: per-token decode cost strictly grows with context.
        let per_tok = |ctx: u64| g.decode_step(32, ctx).time / 32.0;
        assert!(per_tok(8192) > 2.0 * per_tok(256), "kv growth must dominate long ctx");
    }

    #[test]
    fn tp_scales_capacity_and_speed() {
        let g1 = GpuModel::a100_70b_tp8();
        let mut g2 = g1;
        g2.tp = 4;
        // 70B in fp16 = ~138 GB does not fit in 4×40GB with headroom —
        // kv capacity should collapse to ~0; TP8 must have real capacity.
        assert!(g1.kv_token_capacity() > 100_000);
        assert!(g2.kv_token_capacity() < g1.kv_token_capacity());
        // TP8 iteration is faster than TP4 for the same mix.
        let mix = IterationMix { decode_seqs: 16, decode_context: 16 * 512, ..Default::default() };
        assert!(g1.iteration(&mix).time < g2.iteration(&mix).time);
    }

    #[test]
    fn mxu_util_higher_for_prefill_than_small_decode() {
        let g = GpuModel::a100_7b();
        let p = g.prefill(4096);
        let d = g.decode_step(1, 128);
        assert!(p.mxu_util > d.mxu_util, "p={} d={}", p.mxu_util, d.mxu_util);
        // SM-busy util is also lower for a tiny decode step (launch gap
        // dominates a short kernel).
        assert!(p.util > d.util, "p={} d={}", p.util, d.util);
    }

    #[test]
    fn aggregate_decode_throughput_matches_testbed() {
        // Calibration anchor: Llama-2-7b on A100-80 under a serving stack
        // delivers roughly 1–3k decode tokens/s at moderate batch.
        let g = GpuModel::a100_7b();
        let step = g.decode_step(32, 700);
        let tps = 32.0 / step.time;
        assert!((800.0..4000.0).contains(&tps), "tps={tps}");
    }

    #[test]
    fn kv_capacity_is_realistic_for_7b() {
        // A100-80: ~66 GB for KV at 0.5 MB/token → ≈ 120k tokens.
        let g = GpuModel::a100_7b();
        let cap = g.kv_token_capacity();
        assert!((80_000..200_000).contains(&cap), "cap={cap}");
    }

    fn serial_bulk(g: &GpuModel, seqs: u64, ctx0: u64, k: u64) -> (f64, f64) {
        // Reference: k calls to `iteration` with arithmetically growing
        // context — what the per-token engine pays.
        let mut busy = 0.0;
        let mut time = 0.0;
        for j in 0..k {
            let c = g.iteration(&IterationMix {
                decode_seqs: seqs,
                decode_context: ctx0 + j * seqs,
                ..Default::default()
            });
            busy += c.time - g.kernel_const;
            time += c.time;
        }
        (busy, time)
    }

    #[test]
    fn bulk_of_one_matches_single_iteration() {
        let g = GpuModel::a100_7b();
        for (seqs, ctx) in [(1u64, 128u64), (8, 4096), (64, 64 * 700), (256, 256 * 300)] {
            let mix = IterationMix { decode_seqs: seqs, decode_context: ctx, ..Default::default() };
            let single = g.iteration(&mix);
            let bulk = g.iterations_bulk(&mix, 1);
            assert!(
                (bulk.time - single.time).abs() <= 1e-12 * single.time,
                "k=1 bulk {} vs iteration {}",
                bulk.time,
                single.time
            );
            assert!((bulk.busy - (single.time - g.kernel_const)).abs() <= 1e-12 * single.time);
        }
    }

    #[test]
    fn bulk_matches_serial_sum_within_rounding() {
        let g = GpuModel::a100_7b();
        for (seqs, ctx0, k) in [(1u64, 64u64, 500u64), (8, 8 * 256, 1000), (32, 32 * 900, 2000)] {
            let mix =
                IterationMix { decode_seqs: seqs, decode_context: ctx0, ..Default::default() };
            let (busy_ref, time_ref) = serial_bulk(&g, seqs, ctx0, k);
            let bulk = g.iterations_bulk(&mix, k);
            assert!(
                (bulk.busy - busy_ref).abs() <= 1e-9 * busy_ref,
                "busy {} vs serial {} (seqs={seqs} k={k})",
                bulk.busy,
                busy_ref
            );
            assert!((bulk.time - time_ref).abs() <= 1e-9 * time_ref);
        }
    }

    #[test]
    fn bulk_handles_compute_to_memory_regime_flip() {
        // Large batch at small context: compute-bound first iterations,
        // memory-bound once KV reads grow — the closed form must split
        // the series at the same iteration a serial walk flips.
        let g = GpuModel::a100_7b();
        let seqs = 256u64;
        let mix = IterationMix { decode_seqs: seqs, decode_context: 256 * 8, ..Default::default() };
        let first = g.iteration(&mix);
        assert!(first.compute_time > first.memory_time, "window must start compute-bound");
        let k = 6000u64;
        let last = g.iteration(&IterationMix {
            decode_seqs: seqs,
            decode_context: 256 * 8 + (k - 1) * seqs,
            ..Default::default()
        });
        assert!(last.memory_time > last.compute_time, "window must end memory-bound");
        let (busy_ref, _) = serial_bulk(&g, seqs, 256 * 8, k);
        let bulk = g.iterations_bulk(&mix, k);
        assert!(
            (bulk.busy - busy_ref).abs() <= 1e-9 * busy_ref,
            "crossover bulk {} vs serial {}",
            bulk.busy,
            busy_ref
        );
    }

    #[test]
    fn bulk_is_monotone_in_k() {
        let g = GpuModel::a100_7b();
        let mix = IterationMix { decode_seqs: 4, decode_context: 4 * 512, ..Default::default() };
        let mut prev = 0.0;
        for k in [1u64, 2, 10, 100, 10_000] {
            let b = g.iterations_bulk(&mix, k);
            assert!(b.time > prev, "bulk time must grow with k");
            prev = b.time;
        }
    }

    #[test]
    fn batching_amortises_weight_reads() {
        let g = GpuModel::a100_7b();
        let t1 = g.decode_step(1, 256).time;
        let t32 = g.decode_step(32, 256).time;
        // 32× work in much less than 32× time.
        assert!(t32 < 4.0 * t1, "t1={t1} t32={t32}");
    }
}
