//! Discrete-event serving simulator.
//!
//! The paper's testbed (A100-80GB / 8×A100-40GB, Llama-2-7b/70b under
//! S-LoRA, vLLM and SGLang) is substituted by a calibrated roofline model
//! (`gpu`), host profiles capturing the serving-stack knobs that differ
//! between those systems (`host`), and an iteration-level continuous-
//! batching engine (`engine`) that runs any `Scheduler` + `Predictor`
//! combination over any workload `Trace`. The phenomena the paper builds
//! on — Fig 2's monotone latency, non-monotone throughput, and step-wise
//! utilization — *emerge* from the roofline terms rather than being
//! hard-coded (see gpu.rs tests).

pub mod engine;
pub mod gpu;
pub mod host;

pub use engine::{
    advance_until, step_once, Orphan, RunState, SimConfig, SimResult, Simulation, StepMode,
};
pub use gpu::{BulkCost, GpuKind, GpuModel, ModelSpec};
pub use host::HostProfile;
