//! Minimal HTTP/1.1 server: enough for the JSON POST/GET API the
//! examples and the e2e driver exercise. One thread per connection,
//! keep-alive supported, bounded body size.

use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

const MAX_BODY: usize = 1 << 20; // 1 MiB
const MAX_HEADERS: usize = 64;

#[derive(Debug, Clone)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub body: String,
}

#[derive(Debug, Clone)]
pub struct HttpResponse {
    pub status: u16,
    pub body: String,
    pub content_type: &'static str,
}

impl HttpResponse {
    pub fn ok(body: impl Into<String>) -> Self {
        HttpResponse { status: 200, body: body.into(), content_type: "application/json" }
    }

    /// 200 with the Prometheus text exposition content type — the
    /// `/metrics` endpoint's format.
    pub fn text(body: impl Into<String>) -> Self {
        HttpResponse {
            status: 200,
            body: body.into(),
            content_type: "text/plain; version=0.0.4; charset=utf-8",
        }
    }

    pub fn error(status: u16, msg: impl Into<String>) -> Self {
        HttpResponse { status, body: msg.into(), content_type: "application/json" }
    }

    fn reason(&self) -> &'static str {
        match self.status {
            200 => "OK",
            400 => "Bad Request",
            404 => "Not Found",
            429 => "Too Many Requests",
            503 => "Service Unavailable",
            _ => "Internal Server Error",
        }
    }

    fn write_to(&self, stream: &mut TcpStream) -> std::io::Result<()> {
        let head = format!(
            "HTTP/1.1 {} {}\r\ncontent-type: {}\r\ncontent-length: {}\r\n\r\n",
            self.status,
            self.reason(),
            self.content_type,
            self.body.len()
        );
        stream.write_all(head.as_bytes())?;
        stream.write_all(self.body.as_bytes())
    }
}

/// Parse one HTTP/1.1 request from a buffered stream. Returns None on a
/// cleanly closed connection.
fn parse_request(reader: &mut BufReader<TcpStream>) -> Result<Option<HttpRequest>> {
    let mut line = String::new();
    if reader.read_line(&mut line)? == 0 {
        return Ok(None);
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().context("missing method")?.to_string();
    let path = parts.next().context("missing path")?.to_string();
    let mut content_length = 0usize;
    for _ in 0..MAX_HEADERS {
        let mut h = String::new();
        if reader.read_line(&mut h)? == 0 {
            bail!("eof in headers");
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().context("bad content-length")?;
            }
        }
    }
    if content_length > MAX_BODY {
        bail!("body too large");
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok(Some(HttpRequest { method, path, body: String::from_utf8(body).context("non-utf8 body")? }))
}

/// The server: spawns a thread per connection, dispatching to a handler.
pub struct HttpServer {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl HttpServer {
    /// Bind and serve on a background thread. `handler` runs on the
    /// connection thread; it must be cheap or hand off internally.
    pub fn start<F>(addr: &str, handler: F) -> Result<HttpServer>
    where
        F: Fn(HttpRequest) -> HttpResponse + Send + Sync + 'static,
    {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handler = Arc::new(handler);
        let accept_thread = std::thread::spawn(move || {
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let handler = handler.clone();
                        std::thread::spawn(move || handle_conn(stream, handler));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(2));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(HttpServer { addr: local, stop, accept_thread: Some(accept_thread) })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            t.join().ok();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn handle_conn<F>(stream: TcpStream, handler: Arc<F>)
where
    F: Fn(HttpRequest) -> HttpResponse + Send + Sync + 'static,
{
    stream.set_nodelay(true).ok();
    let mut writer = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    loop {
        match parse_request(&mut reader) {
            Ok(Some(req)) => {
                let resp = handler(req);
                if resp.write_to(&mut writer).is_err() {
                    return;
                }
            }
            Ok(None) => return,
            Err(_) => {
                HttpResponse::error(400, "{\"error\":\"bad request\"}")
                    .write_to(&mut writer)
                    .ok();
                return;
            }
        }
    }
}

/// A tiny blocking HTTP client for the examples and tests.
pub fn http_post(addr: &std::net::SocketAddr, path: &str, body: &str) -> Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    let req = format!(
        "POST {path} HTTP/1.1\r\nhost: {addr}\r\ncontent-length: {}\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes())?;
    read_response(stream)
}

pub fn http_get(addr: &std::net::SocketAddr, path: &str) -> Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    let req = format!("GET {path} HTTP/1.1\r\nhost: {addr}\r\ncontent-length: 0\r\n\r\n");
    stream.write_all(req.as_bytes())?;
    read_response(stream)
}

fn read_response(stream: TcpStream) -> Result<(u16, String)> {
    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line)?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .context("bad status line")?
        .parse()?;
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse()?;
            }
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok((status, String::from_utf8(body)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_post_and_get() {
        let mut server = HttpServer::start("127.0.0.1:0", |req| {
            if req.path == "/echo" {
                HttpResponse::ok(req.body)
            } else {
                HttpResponse::error(404, "{}")
            }
        })
        .unwrap();
        let addr = server.addr();
        let (st, body) = http_post(&addr, "/echo", r#"{"x":1}"#).unwrap();
        assert_eq!(st, 200);
        assert_eq!(body, r#"{"x":1}"#);
        let (st, _) = http_get(&addr, "/nope").unwrap();
        assert_eq!(st, 404);
        server.stop();
    }

    #[test]
    fn concurrent_clients() {
        let server = HttpServer::start("127.0.0.1:0", |req| HttpResponse::ok(req.body)).unwrap();
        let addr = server.addr();
        let handles: Vec<_> = (0..8)
            .map(|i| {
                std::thread::spawn(move || {
                    let (st, body) = http_post(&addr, "/", &format!("{i}")).unwrap();
                    assert_eq!(st, 200);
                    assert_eq!(body, format!("{i}"));
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
}
