//! Server frontend (§4 component i) and service wiring: a minimal
//! HTTP/1.1 server over std TCP (the offline registry lacks tokio/hyper —
//! DESIGN.md substitution ledger), request validation and RPM-style rate
//! limiting, and the coordinator loop binding frontend → queues →
//! holistic-fairness scheduler → TinyLM engine.

pub mod frontend;
pub mod http;
pub mod service;

pub use frontend::{AdmissionError, Frontend, FrontendConfig};
pub use http::{HttpRequest, HttpResponse, HttpServer};
pub use service::{ServeService, ServiceConfig, ServiceStats};
