//! Server frontend (§4 ①): authentication-ish client identification,
//! semantic validation, and optional RPM rate limiting before requests
//! reach the queues.

use crate::core::ClientId;
use crate::runtime::tokenizer;
use std::collections::BTreeMap;
use std::collections::VecDeque;

/// Frontend policy knobs.
#[derive(Debug, Clone)]
pub struct FrontendConfig {
    /// Max prompt tokens accepted (semantic validation).
    pub max_input_tokens: u32,
    /// Max requested output tokens.
    pub max_output_tokens: u32,
    /// Optional RPM cap per client (None = no static quota; Equinox's
    /// point is that fair scheduling replaces quotas).
    pub rpm_quota: Option<u32>,
    pub rpm_window: f64,
}

impl Default for FrontendConfig {
    fn default() -> Self {
        FrontendConfig {
            max_input_tokens: 256,
            max_output_tokens: 256,
            rpm_quota: None,
            rpm_window: 60.0,
        }
    }
}

/// Why a request was dropped at the door.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AdmissionError {
    EmptyPrompt,
    PromptTooLong { tokens: u32, max: u32 },
    OutputTooLong { tokens: u32, max: u32 },
    RateLimited { client: ClientId },
    UnknownClient,
}

impl std::fmt::Display for AdmissionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmissionError::EmptyPrompt => write!(f, "empty prompt"),
            AdmissionError::PromptTooLong { tokens, max } => {
                write!(f, "prompt of {tokens} tokens exceeds max {max}")
            }
            AdmissionError::OutputTooLong { tokens, max } => {
                write!(f, "requested {tokens} output tokens exceeds max {max}")
            }
            AdmissionError::RateLimited { client } => write!(f, "client {client} over RPM quota"),
            AdmissionError::UnknownClient => write!(f, "missing or invalid client id"),
        }
    }
}

/// A validated request ready for the queues.
#[derive(Debug, Clone)]
pub struct ValidatedRequest {
    pub client: ClientId,
    pub prompt: String,
    pub prompt_tokens: Vec<i32>,
    pub max_new_tokens: u32,
}

/// The frontend: validation + per-client RPM accounting.
#[derive(Debug)]
pub struct Frontend {
    pub config: FrontendConfig,
    admissions: BTreeMap<ClientId, VecDeque<f64>>,
    /// Next time the amortized expiry sweep runs (see `sweep_expired`).
    next_sweep: f64,
    /// Counters for observability.
    pub accepted: u64,
    pub rejected: u64,
}

impl Frontend {
    pub fn new(config: FrontendConfig) -> Self {
        Frontend {
            config,
            admissions: BTreeMap::new(),
            next_sweep: 0.0,
            accepted: 0,
            rejected: 0,
        }
    }

    /// Number of clients with live rate-limit state (observability hook).
    pub fn tracked_clients(&self) -> usize {
        self.admissions.len()
    }

    /// Amortized cleanup, at most once per RPM window: drop clients whose
    /// stamps have all expired. Per-client pruning only runs when that
    /// client sends again, so without this sweep the admissions map keeps
    /// one entry for every client ever seen — a slow leak under
    /// short-lived-tenant churn.
    fn sweep_expired(&mut self, now: f64) {
        if now < self.next_sweep {
            return;
        }
        let window = self.config.rpm_window;
        self.admissions.retain(|_, stamps| {
            while stamps.front().map(|&t| now - t >= window).unwrap_or(false) {
                stamps.pop_front();
            }
            !stamps.is_empty()
        });
        self.next_sweep = now + window;
    }

    /// Validate and admit a raw request.
    pub fn admit(
        &mut self,
        client: ClientId,
        prompt: &str,
        max_new_tokens: u32,
        now: f64,
    ) -> Result<ValidatedRequest, AdmissionError> {
        let result = self.validate(client, prompt, max_new_tokens, now);
        match &result {
            Ok(_) => self.accepted += 1,
            Err(_) => self.rejected += 1,
        }
        result
    }

    fn validate(
        &mut self,
        client: ClientId,
        prompt: &str,
        max_new_tokens: u32,
        now: f64,
    ) -> Result<ValidatedRequest, AdmissionError> {
        if prompt.trim().is_empty() {
            return Err(AdmissionError::EmptyPrompt);
        }
        let tokens = tokenizer::count_tokens(prompt);
        if tokens > self.config.max_input_tokens {
            return Err(AdmissionError::PromptTooLong { tokens, max: self.config.max_input_tokens });
        }
        if max_new_tokens == 0 || max_new_tokens > self.config.max_output_tokens {
            return Err(AdmissionError::OutputTooLong {
                tokens: max_new_tokens,
                max: self.config.max_output_tokens,
            });
        }
        if let Some(quota) = self.config.rpm_quota {
            let window = self.config.rpm_window;
            self.sweep_expired(now);
            // Prune this client's expired stamps; drop the entry outright
            // when nothing is left so rejected/idle clients hold no state.
            let live = match self.admissions.get_mut(&client) {
                Some(stamps) => {
                    while stamps.front().map(|&t| now - t >= window).unwrap_or(false) {
                        stamps.pop_front();
                    }
                    if stamps.is_empty() {
                        self.admissions.remove(&client);
                        0
                    } else {
                        stamps.len() as u32
                    }
                }
                None => 0,
            };
            if live >= quota {
                return Err(AdmissionError::RateLimited { client });
            }
            self.admissions.entry(client).or_default().push_back(now);
        }
        Ok(ValidatedRequest {
            client,
            prompt: prompt.to_string(),
            prompt_tokens: tokenizer::encode(prompt),
            max_new_tokens,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frontend(quota: Option<u32>) -> Frontend {
        Frontend::new(FrontendConfig { rpm_quota: quota, ..Default::default() })
    }

    #[test]
    fn accepts_valid_request() {
        let mut f = frontend(None);
        let v = f.admit(ClientId(1), "what is rust?", 64, 0.0).unwrap();
        assert_eq!(v.client, ClientId(1));
        assert!(!v.prompt_tokens.is_empty());
        assert_eq!(f.accepted, 1);
    }

    #[test]
    fn rejects_empty_and_oversized() {
        let mut f = frontend(None);
        assert_eq!(f.admit(ClientId(1), "  ", 10, 0.0).unwrap_err(), AdmissionError::EmptyPrompt);
        let long = "w ".repeat(500);
        assert!(matches!(
            f.admit(ClientId(1), &long, 10, 0.0),
            Err(AdmissionError::PromptTooLong { .. })
        ));
        assert!(matches!(
            f.admit(ClientId(1), "hi there", 0, 0.0),
            Err(AdmissionError::OutputTooLong { .. })
        ));
        assert_eq!(f.rejected, 3);
    }

    #[test]
    fn rpm_quota_enforced_and_expires() {
        let mut f = frontend(Some(2));
        assert!(f.admit(ClientId(1), "a b", 10, 0.0).is_ok());
        assert!(f.admit(ClientId(1), "a b", 10, 1.0).is_ok());
        assert_eq!(
            f.admit(ClientId(1), "a b", 10, 2.0).unwrap_err(),
            AdmissionError::RateLimited { client: ClientId(1) }
        );
        // Other clients unaffected.
        assert!(f.admit(ClientId(2), "a b", 10, 2.0).is_ok());
        // Window expiry.
        assert!(f.admit(ClientId(1), "a b", 10, 61.0).is_ok());
    }

    #[test]
    fn one_shot_client_burst_leaves_no_state_behind() {
        let mut f = frontend(Some(2));
        for c in 0..1000u32 {
            assert!(f.admit(ClientId(c), "a b", 10, 0.01 * c as f64).is_ok());
        }
        assert_eq!(f.tracked_clients(), 1000);
        // One admit past the window triggers the amortized sweep: every
        // one-shot client's stamps have expired, so their entries vanish
        // and only the fresh client remains tracked.
        assert!(f.admit(ClientId(5000), "a b", 10, 100.0).is_ok());
        assert_eq!(f.tracked_clients(), 1);
    }

    #[test]
    fn zero_quota_rejection_tracks_nothing() {
        let mut f = frontend(Some(0));
        for c in 0..64u32 {
            assert!(matches!(
                f.admit(ClientId(c), "a b", 10, 1.0),
                Err(AdmissionError::RateLimited { .. })
            ));
        }
        assert_eq!(f.tracked_clients(), 0);
    }
}
