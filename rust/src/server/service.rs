//! The coordinator service: frontend → MoPE prediction → holistic-fair
//! scheduler → TinyLM engine, on real threads with Python nowhere in
//! sight. This is the production-shaped path; the simulator reproduces
//! the paper's figures at A100 scale, this serves real tokens.

use crate::core::{Clock, ClientId, Request, RequestId, SystemClock};
use crate::predictor::PerfMap;
use crate::runtime::engine::{EngineConfig, ServeEngine};
use crate::runtime::features;
use crate::runtime::mope_rt::MopePredictor;
use crate::runtime::pjrt::Runtime;
use crate::runtime::tokenizer;
use crate::sched::{Actuals, EquinoxSched, GuardPolicy, Scheduler};
use crate::server::frontend::{Frontend, FrontendConfig, ValidatedRequest};
use crate::util::stats::Welford;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender, SyncSender};
use std::sync::{mpsc, Arc, Mutex};

/// Service configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub artifacts: std::path::PathBuf,
    pub frontend: FrontendConfig,
    /// Scheduler α (UFC weight).
    pub alpha: f64,
}

impl ServiceConfig {
    pub fn new(artifacts: impl Into<std::path::PathBuf>) -> Self {
        ServiceConfig { artifacts: artifacts.into(), frontend: FrontendConfig::default(), alpha: 0.7 }
    }
}

/// One completed generation.
#[derive(Debug, Clone)]
pub struct Completion {
    pub request: RequestId,
    pub client: ClientId,
    pub text: String,
    pub output_tokens: u32,
    pub ttft: f64,
    pub e2e: f64,
}

/// Aggregated serving stats (thread-safe snapshotting).
#[derive(Debug)]
pub struct ServiceStats {
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    pub output_tokens: AtomicU64,
    /// Queued requests at the last coordinator iteration.
    pub queue_depth: AtomicU64,
    /// Distinct backlogged clients at the last coordinator iteration
    /// (an O(1) read via `Scheduler::queued_client_count`).
    pub backlogged_clients: AtomicU64,
    /// Worst per-regime |log error| EWMA of the calibration guard,
    /// stored as `f64` bits (0.0 until a regime is seasoned).
    pub pred_abs_err_ewma: AtomicU64,
    /// Multiplicative correction the guard applies to predicted-token
    /// admission charges, stored as `f64` bits (1.0 = no correction).
    pub pred_debias_factor: AtomicU64,
    /// Guard degradation-ladder rung (`GuardMode::code()`):
    /// 0 predictive, 1 debiased, 2 actual-only.
    pub guard_mode: AtomicU64,
    pub ttft: Mutex<Welford>,
    pub e2e: Mutex<Welford>,
}

impl Default for ServiceStats {
    fn default() -> Self {
        ServiceStats {
            completed: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            output_tokens: AtomicU64::new(0),
            queue_depth: AtomicU64::new(0),
            backlogged_clients: AtomicU64::new(0),
            pred_abs_err_ewma: AtomicU64::new(0.0f64.to_bits()),
            // Identity correction until the guard's first snapshot — a
            // plain zero would read as "charges multiplied by 0".
            pred_debias_factor: AtomicU64::new(1.0f64.to_bits()),
            guard_mode: AtomicU64::new(0),
            ttft: Mutex::new(Welford::default()),
            e2e: Mutex::new(Welford::default()),
        }
    }
}

impl ServiceStats {
    pub fn snapshot_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let ttft = self.ttft.lock().unwrap();
        let e2e = self.e2e.lock().unwrap();
        Json::obj()
            .set("completed", self.completed.load(Ordering::Relaxed))
            .set("rejected", self.rejected.load(Ordering::Relaxed))
            .set("output_tokens", self.output_tokens.load(Ordering::Relaxed))
            .set("queue_depth", self.queue_depth.load(Ordering::Relaxed))
            .set("backlogged_clients", self.backlogged_clients.load(Ordering::Relaxed))
            .set(
                "pred_abs_err_ewma",
                f64::from_bits(self.pred_abs_err_ewma.load(Ordering::Relaxed)),
            )
            .set(
                "pred_debias_factor",
                f64::from_bits(self.pred_debias_factor.load(Ordering::Relaxed)),
            )
            .set("guard_mode", self.guard_mode.load(Ordering::Relaxed))
            .set("ttft_mean_s", ttft.mean())
            .set("ttft_max_s", ttft.max())
            .set("e2e_mean_s", e2e.mean())
            .set("e2e_max_s", e2e.max())
    }
}

/// Render the coordinator gauges in Prometheus text exposition format
/// (`text/plain; version=0.0.4`). Pure function of the snapshot values
/// so it is unit-testable without a running engine.
pub fn prometheus_text(
    stats: &ServiceStats,
    fe_accepted: u64,
    fe_rejected: u64,
    tracked_clients: usize,
) -> String {
    let ttft = stats.ttft.lock().unwrap();
    let e2e = stats.e2e.lock().unwrap();
    let mut out = String::with_capacity(1024);
    let mut metric = |name: &str, kind: &str, help: &str, value: f64| {
        out.push_str(&format!(
            "# HELP {name} {help}\n# TYPE {name} {kind}\n{name} {value}\n"
        ));
    };
    metric(
        "equinox_requests_completed_total",
        "counter",
        "Generations completed by the coordinator.",
        stats.completed.load(Ordering::Relaxed) as f64,
    );
    metric(
        "equinox_requests_rejected_total",
        "counter",
        "Submissions rejected by frontend admission.",
        stats.rejected.load(Ordering::Relaxed) as f64,
    );
    metric(
        "equinox_output_tokens_total",
        "counter",
        "Output tokens emitted across all completions.",
        stats.output_tokens.load(Ordering::Relaxed) as f64,
    );
    metric(
        "equinox_queue_depth",
        "gauge",
        "Requests queued in the scheduler at the last coordinator iteration.",
        stats.queue_depth.load(Ordering::Relaxed) as f64,
    );
    metric(
        "equinox_backlogged_clients",
        "gauge",
        "Distinct clients with queued work at the last coordinator iteration.",
        stats.backlogged_clients.load(Ordering::Relaxed) as f64,
    );
    metric(
        "equinox_frontend_accepted_total",
        "counter",
        "Requests accepted by frontend validation and rate limiting.",
        fe_accepted as f64,
    );
    metric(
        "equinox_frontend_rejected_total",
        "counter",
        "Requests rejected by frontend validation and rate limiting.",
        fe_rejected as f64,
    );
    metric(
        "equinox_frontend_tracked_clients",
        "gauge",
        "Clients with live rate-limiter state in the frontend.",
        tracked_clients as f64,
    );
    metric(
        "equinox_pred_abs_err_ewma",
        "gauge",
        "Worst per-regime |log error| EWMA of the prediction calibration guard.",
        f64::from_bits(stats.pred_abs_err_ewma.load(Ordering::Relaxed)),
    );
    metric(
        "equinox_pred_debias_factor",
        "gauge",
        "Multiplicative correction applied to predicted-token admission charges (1 = none).",
        f64::from_bits(stats.pred_debias_factor.load(Ordering::Relaxed)),
    );
    metric(
        "equinox_guard_mode",
        "gauge",
        "Guard degradation-ladder rung: 0 predictive, 1 debiased, 2 actual-only.",
        stats.guard_mode.load(Ordering::Relaxed) as f64,
    );
    metric(
        "equinox_ttft_seconds_mean",
        "gauge",
        "Mean time-to-first-token over completed requests.",
        ttft.mean(),
    );
    metric(
        "equinox_e2e_seconds_mean",
        "gauge",
        "Mean end-to-end latency over completed requests.",
        e2e.mean(),
    );
    out
}

struct Submission {
    validated: ValidatedRequest,
    respond: SyncSender<Completion>,
    submitted_at: f64,
}

/// The running service: submission API + coordinator thread.
pub struct ServeService {
    tx: Sender<Submission>,
    frontend: Mutex<Frontend>,
    pub stats: Arc<ServiceStats>,
    clock: Arc<SystemClock>,
    stop: Arc<AtomicBool>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl ServeService {
    /// Load artifacts and start the coordinator thread.
    pub fn start(cfg: ServiceConfig) -> Result<ServeService> {
        let clock = Arc::new(SystemClock::new());
        let stats = Arc::new(ServiceStats::default());
        let stop = Arc::new(AtomicBool::new(false));
        let (tx, rx) = mpsc::channel::<Submission>();

        // Load the runtime on the coordinator thread (engine is !Sync);
        // block start() until loading finishes so failures surface here.
        let (ready_tx, ready_rx) = mpsc::sync_channel::<Result<()>>(1);
        let artifacts = cfg.artifacts.clone();
        let alpha = cfg.alpha;
        let clock2 = clock.clone();
        let stats2 = stats.clone();
        let stop2 = stop.clone();
        let worker = std::thread::spawn(move || {
            let built = (|| -> Result<(Runtime, ServeEngine, MopePredictor)> {
                let rt = Runtime::cpu()?;
                let engine = ServeEngine::new(&rt, &EngineConfig::new(&artifacts))
                    .context("loading TinyLM artifacts")?;
                let mope = MopePredictor::load(&rt, &engine.manifest)?;
                Ok((rt, engine, mope))
            })();
            match built {
                Ok((_rt, engine, mope)) => {
                    ready_tx.send(Ok(())).ok();
                    coordinator_loop(engine, mope, rx, clock2, stats2, stop2, alpha);
                }
                Err(e) => {
                    ready_tx.send(Err(e)).ok();
                }
            }
        });
        ready_rx.recv().context("coordinator thread died")??;
        Ok(ServeService {
            tx,
            frontend: Mutex::new(Frontend::new(cfg.frontend)),
            stats,
            clock,
            stop,
            worker: Some(worker),
        })
    }

    /// Submit a prompt; returns a receiver that yields the completion.
    pub fn submit(
        &self,
        client: ClientId,
        prompt: &str,
        max_new_tokens: u32,
    ) -> Result<Receiver<Completion>, crate::server::frontend::AdmissionError> {
        let now = self.clock.now();
        let validated = {
            let mut fe = self.frontend.lock().unwrap();
            fe.admit(client, prompt, max_new_tokens, now)
        };
        let validated = match validated {
            Ok(v) => v,
            Err(e) => {
                self.stats.rejected.fetch_add(1, Ordering::Relaxed);
                return Err(e);
            }
        };
        let (ctx, crx) = mpsc::sync_channel(1);
        self.tx
            .send(Submission { validated, respond: ctx, submitted_at: now })
            .expect("coordinator alive");
        Ok(crx)
    }

    /// Submit and wait (convenience).
    pub fn generate(&self, client: ClientId, prompt: &str, max_new: u32) -> Result<Completion> {
        let rx = self
            .submit(client, prompt, max_new)
            .map_err(|e| anyhow::anyhow!("admission: {e}"))?;
        rx.recv().context("service stopped before completion")
    }

    /// The `/metrics` payload: coordinator gauges plus frontend
    /// rate-limit counters, Prometheus text format.
    pub fn metrics_prometheus(&self) -> String {
        let (accepted, rejected, tracked) = {
            let fe = self.frontend.lock().unwrap();
            (fe.accepted, fe.rejected, fe.tracked_clients())
        };
        prometheus_text(&self.stats, accepted, rejected, tracked)
    }

    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(w) = self.worker.take() {
            w.join().ok();
        }
    }
}

impl Drop for ServeService {
    fn drop(&mut self) {
        self.stop();
    }
}

struct InFlight {
    req: Request,
    respond: SyncSender<Completion>,
    tokens: Vec<i32>,
    prefill_done_at: f64,
    admitted_at: f64,
}

#[allow(clippy::too_many_arguments)]
fn coordinator_loop(
    mut engine: ServeEngine,
    mope: MopePredictor,
    rx: Receiver<Submission>,
    clock: Arc<SystemClock>,
    stats: Arc<ServiceStats>,
    stop: Arc<AtomicBool>,
    alpha: f64,
) {
    // Full hysteresis ladder on the serving path: MoPE mispredictions
    // are debiased online, and a miscalibrated regime degrades charging
    // to actual-only instead of letting a biased predictor skew HF.
    let mut sched = EquinoxSched::with_guard(
        crate::sched::counters::HfParams::with_alpha(alpha),
        // Peak TPS for RFC normalisation — TinyLM on CPU is ~hundreds/s.
        500.0,
        GuardPolicy::Ladder,
    );
    let perfmap = PerfMap::default_a100_7b();
    let mut side: HashMap<RequestId, (ValidatedRequest, SyncSender<Completion>)> = HashMap::new();
    let mut slots: HashMap<usize, InFlight> = HashMap::new();
    let mut next_id = 0u64;

    loop {
        if stop.load(Ordering::Relaxed) {
            return;
        }
        // ---- ingest submissions (non-blocking) ----
        while let Ok(sub) = rx.try_recv() {
            let id = RequestId(next_id);
            next_id += 1;
            let mut req = Request::new(
                id,
                sub.validated.client,
                sub.validated.prompt_tokens.len() as u32,
                sub.validated.max_new_tokens,
                sub.submitted_at,
            );
            req.prompt = Some(sub.validated.prompt.clone());
            // MoPE prediction (AOT expert) + PerfMap mapping.
            let feats = features::extract(&sub.validated.prompt, req.input_tokens);
            let predicted = mope.predict(&[feats]).map(|v| v[0]).unwrap_or(64);
            req.predicted_output_tokens = predicted.min(sub.validated.max_new_tokens);
            let mapped = perfmap.map(req.input_tokens, req.predicted_output_tokens);
            req.predicted_latency = mapped.latency;
            req.predicted_gpu_util = mapped.gpu_util;
            req.predicted_tps = mapped.tps;
            side.insert(id, (sub.validated, sub.respond));
            sched.enqueue(req, sub.submitted_at);
        }

        // ---- admission into engine slots ----
        let now = clock.now();
        loop {
            if engine.free_slots() == 0 {
                break;
            }
            let picked = sched.pick(now, &mut |r: &Request| {
                engine.can_admit(r.input_tokens as usize, r.true_output_tokens as usize)
            });
            let Some(req) = picked else { break };
            let (validated, respond) = side.remove(&req.id).expect("side table");
            match engine.add_request(&validated.prompt_tokens, req.true_output_tokens as usize) {
                Ok((slot, first_token)) => {
                    let t = clock.now();
                    slots.insert(
                        slot,
                        InFlight {
                            req,
                            respond,
                            tokens: vec![first_token],
                            prefill_done_at: t,
                            admitted_at: now,
                        },
                    );
                }
                Err(_) => {
                    // Shouldn't happen after can_admit; requeue defensively.
                    side.insert(req.id, (validated, respond));
                    sched.requeue(req);
                    break;
                }
            }
        }

        // ---- backlog gauges (O(1) reads off the scheduler) ----
        stats.queue_depth.store(sched.queue_len() as u64, Ordering::Relaxed);
        stats
            .backlogged_clients
            .store(sched.queued_client_count() as u64, Ordering::Relaxed);
        if let Some(h) = sched.guard_health() {
            stats.pred_abs_err_ewma.store(h.abs_err_ewma.to_bits(), Ordering::Relaxed);
            stats.pred_debias_factor.store(h.debias_factor.to_bits(), Ordering::Relaxed);
            stats.guard_mode.store(h.mode.code() as u64, Ordering::Relaxed);
        }

        // ---- decode step ----
        let events = match engine.step() {
            Ok(ev) => ev,
            Err(_) => Vec::new(),
        };
        let now = clock.now();
        let mut finished_slots = Vec::new();
        for ev in events {
            if let Some(inf) = slots.get_mut(&ev.slot) {
                inf.tokens.push(ev.token);
                if ev.finished {
                    finished_slots.push(ev.slot);
                }
            }
        }
        // Also handle 1-token generations (finished at prefill).
        let one_shots: Vec<usize> = slots
            .iter()
            .filter(|(slot, inf)| {
                inf.req.true_output_tokens <= 1 && !finished_slots.contains(slot)
            })
            .map(|(s, _)| *s)
            .collect();
        finished_slots.extend(one_shots);

        for slot in finished_slots {
            let inf = slots.remove(&slot).unwrap();
            let ttft = inf.prefill_done_at - inf.req.arrival;
            let e2e = now - inf.req.arrival;
            let out = inf.tokens.len() as u32;
            let exec = (now - inf.admitted_at).max(1e-9);
            let actuals = Actuals {
                latency: exec,
                gpu_util: 1.0, // CPU engine: busy whenever stepping
                tps: (inf.req.input_tokens + out) as f64 / exec,
                output_tokens: out,
            };
            sched.on_complete(&inf.req, &actuals, now);
            stats.completed.fetch_add(1, Ordering::Relaxed);
            stats.output_tokens.fetch_add(out as u64, Ordering::Relaxed);
            stats.ttft.lock().unwrap().push(ttft);
            stats.e2e.lock().unwrap().push(e2e);
            inf.respond
                .send(Completion {
                    request: inf.req.id,
                    client: inf.req.client,
                    text: tokenizer::decode(&inf.tokens),
                    output_tokens: out,
                    ttft,
                    e2e,
                })
                .ok();
        }

        // ---- idle parking ----
        if engine.occupied() == 0 && sched.is_empty() {
            match rx.recv_timeout(std::time::Duration::from_millis(20)) {
                Ok(sub) => {
                    // Re-inject through the same path next iteration.
                    let id = RequestId(next_id);
                    next_id += 1;
                    let mut req = Request::new(
                        id,
                        sub.validated.client,
                        sub.validated.prompt_tokens.len() as u32,
                        sub.validated.max_new_tokens,
                        sub.submitted_at,
                    );
                    req.prompt = Some(sub.validated.prompt.clone());
                    let feats = features::extract(&sub.validated.prompt, req.input_tokens);
                    let predicted = mope.predict(&[feats]).map(|v| v[0]).unwrap_or(64);
                    req.predicted_output_tokens = predicted.min(sub.validated.max_new_tokens);
                    side.insert(id, (sub.validated, sub.respond));
                    sched.enqueue(req, clock.now());
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => return,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prometheus_text_exposes_every_gauge() {
        let stats = ServiceStats::default();
        stats.completed.store(7, Ordering::Relaxed);
        stats.queue_depth.store(3, Ordering::Relaxed);
        stats.backlogged_clients.store(2, Ordering::Relaxed);
        stats.ttft.lock().unwrap().push(0.5);
        stats.pred_abs_err_ewma.store(0.25f64.to_bits(), Ordering::Relaxed);
        stats.pred_debias_factor.store(1.5f64.to_bits(), Ordering::Relaxed);
        stats.guard_mode.store(1, Ordering::Relaxed);
        let text = prometheus_text(&stats, 11, 4, 5);
        for name in [
            "equinox_requests_completed_total 7",
            "equinox_queue_depth 3",
            "equinox_backlogged_clients 2",
            "equinox_frontend_accepted_total 11",
            "equinox_frontend_rejected_total 4",
            "equinox_frontend_tracked_clients 5",
            "equinox_pred_abs_err_ewma 0.25",
            "equinox_pred_debias_factor 1.5",
            "equinox_guard_mode 1",
            "equinox_ttft_seconds_mean 0.5",
        ] {
            assert!(text.contains(name), "missing `{name}` in:\n{text}");
        }
        // Every metric carries HELP and TYPE headers (the exposition
        // format scrapers validate).
        assert_eq!(text.matches("# HELP ").count(), text.matches("# TYPE ").count());
        assert!(text.ends_with('\n'));
    }

    /// Before the guard's first snapshot the gauges must read as the
    /// identity: factor 1 (not 0 — that would mean "charges zeroed"),
    /// mode 0 (predictive), error 0.
    #[test]
    fn guard_gauges_default_to_identity() {
        let stats = ServiceStats::default();
        let text = prometheus_text(&stats, 0, 0, 0);
        assert!(text.contains("equinox_pred_debias_factor 1\n"), "{text}");
        assert!(text.contains("equinox_guard_mode 0\n"), "{text}");
        assert!(text.contains("equinox_pred_abs_err_ewma 0\n"), "{text}");
    }
}
