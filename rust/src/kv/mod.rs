//! Paged KV-cache manager — the PagedAttention-style substrate the paper's
//! host systems (vLLM/SGLang) rely on. Both the simulator (memory
//! feasibility in `can_schedule`) and the real runtime engine (slot
//! assignment for the TinyLM decode batch) use this allocator.

use crate::core::RequestId;
use std::collections::HashMap;

/// Configuration of the paged pool.
#[derive(Debug, Clone, Copy)]
pub struct KvConfig {
    /// Tokens per page (vLLM default 16).
    pub page_size: u32,
    /// Total pages in the pool.
    pub total_pages: u32,
}

impl KvConfig {
    /// Derive a pool from GPU memory: `bytes_per_token` is
    /// 2 (K+V) · layers · kv_heads · head_dim · dtype_bytes.
    pub fn from_memory(bytes: u64, bytes_per_token: u64, page_size: u32) -> KvConfig {
        let tokens = bytes / bytes_per_token.max(1);
        KvConfig { page_size, total_pages: (tokens / page_size as u64) as u32 }
    }

    pub fn total_tokens(&self) -> u64 {
        self.page_size as u64 * self.total_pages as u64
    }
}

/// Errors from the allocator.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum KvError {
    OutOfMemory { requested_pages: u32, free_pages: u32 },
    UnknownRequest(RequestId),
    AlreadyAllocated(RequestId),
}

impl std::fmt::Display for KvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KvError::OutOfMemory { requested_pages, free_pages } => {
                write!(f, "KV OOM: requested {requested_pages} pages, {free_pages} free")
            }
            KvError::UnknownRequest(id) => write!(f, "unknown request {id}"),
            KvError::AlreadyAllocated(id) => write!(f, "request {id} already has a page table"),
        }
    }
}

impl std::error::Error for KvError {}

/// Per-request page table.
#[derive(Debug, Clone, Default)]
struct PageTable {
    pages: Vec<u32>,
    tokens: u32,
}

/// The paged allocator. Free pages are a LIFO stack for locality.
#[derive(Debug)]
pub struct KvCache {
    config: KvConfig,
    free: Vec<u32>,
    tables: HashMap<RequestId, PageTable>,
    /// High-water mark of allocated pages (for fragmentation stats).
    peak_used: u32,
    /// Pages withheld from allocation (fault injection: `KvShrink`).
    /// Purely a gate on future allocation/growth — the free stack keeps
    /// its physical pages, so lifting the reservation restores them.
    reserved_pages: u32,
}

impl KvCache {
    pub fn new(config: KvConfig) -> Self {
        KvCache {
            config,
            free: (0..config.total_pages).rev().collect(),
            tables: HashMap::new(),
            peak_used: 0,
            reserved_pages: 0,
        }
    }

    pub fn config(&self) -> KvConfig {
        self.config
    }

    /// Pages available for allocation: the free stack minus the fault
    /// reservation. Already-allocated pages are never reclaimed by a
    /// reservation — a shrink can transiently leave fewer physically
    /// free pages than reserved (then this reads 0 until releases catch
    /// up), which models a capacity loss without corrupting live tables.
    pub fn free_pages(&self) -> u32 {
        (self.free.len() as u32).saturating_sub(self.reserved_pages)
    }

    /// Physically allocated pages (ignores the reservation — reserved
    /// pages are unavailable, not used, so conservation stats and the
    /// peak-usage high-water mark stay reservation-independent).
    pub fn used_pages(&self) -> u32 {
        self.config.total_pages - self.free.len() as u32
    }

    pub fn peak_used_pages(&self) -> u32 {
        self.peak_used
    }

    /// Withhold `pages` from allocation (clamped to the pool size);
    /// 0 lifts the reservation. Gates `allocate`/`grow`/`can_grow` only.
    pub fn set_reserved_pages(&mut self, pages: u32) {
        self.reserved_pages = pages.min(self.config.total_pages);
    }

    pub fn reserved_pages(&self) -> u32 {
        self.reserved_pages
    }

    /// Free token capacity (pages × page_size minus nothing — pages are
    /// only partially filled at the tail of each sequence).
    pub fn free_tokens(&self) -> u64 {
        self.free_pages() as u64 * self.config.page_size as u64
    }

    fn pages_for(&self, tokens: u32) -> u32 {
        tokens.div_ceil(self.config.page_size)
    }

    /// Whether `tokens` MORE tokens could be stored for a (possibly new)
    /// request that currently holds `current` tokens.
    pub fn can_grow(&self, current: u32, extra: u32) -> bool {
        let have = self.pages_for(current);
        let need = self.pages_for(current + extra);
        need - have <= self.free_pages()
    }

    /// Allocate a page table covering `tokens` tokens for a new request.
    pub fn allocate(&mut self, id: RequestId, tokens: u32) -> Result<(), KvError> {
        if self.tables.contains_key(&id) {
            return Err(KvError::AlreadyAllocated(id));
        }
        let need = self.pages_for(tokens);
        if need > self.free_pages() {
            return Err(KvError::OutOfMemory { requested_pages: need, free_pages: self.free_pages() });
        }
        let pages: Vec<u32> = (0..need).map(|_| self.free.pop().unwrap()).collect();
        self.tables.insert(id, PageTable { pages, tokens });
        self.peak_used = self.peak_used.max(self.used_pages());
        Ok(())
    }

    /// Extend a request's table by `extra` tokens (decode step growth).
    /// Shares `grow_bulk`'s allocation path, so per-token and bulk growth
    /// are identical by construction, not by parallel maintenance.
    pub fn grow(&mut self, id: RequestId, extra: u32) -> Result<(), KvError> {
        self.grow_bulk(id, extra).map(|_| ())
    }

    /// Extend a request's table by `extra` tokens in one call, returning
    /// the number of pages newly allocated. Identical allocation outcome
    /// to `extra` single-token [`KvCache::grow`] calls (pages are claimed
    /// only at page-size boundaries), but O(pages) instead of O(tokens) —
    /// the macro-stepping engine grows a whole event-horizon window at
    /// once. All-or-nothing: on OOM no pages are taken and the table is
    /// unchanged.
    pub fn grow_bulk(&mut self, id: RequestId, extra: u32) -> Result<u32, KvError> {
        let table = self.tables.get_mut(&id).ok_or(KvError::UnknownRequest(id))?;
        let have = table.pages.len() as u32;
        let need = (table.tokens + extra).div_ceil(self.config.page_size);
        let more = need.saturating_sub(have);
        if more > (self.free.len() as u32).saturating_sub(self.reserved_pages) {
            return Err(KvError::OutOfMemory {
                requested_pages: more,
                free_pages: (self.free.len() as u32).saturating_sub(self.reserved_pages),
            });
        }
        let start = self.free.len() - more as usize;
        table.pages.extend(self.free.drain(start..).rev());
        table.tokens += extra;
        self.peak_used = self.peak_used.max(self.used_pages());
        Ok(more)
    }

    /// Release all pages of a finished request.
    pub fn release(&mut self, id: RequestId) -> Result<u32, KvError> {
        let table = self.tables.remove(&id).ok_or(KvError::UnknownRequest(id))?;
        let n = table.pages.len() as u32;
        self.free.extend(table.pages);
        Ok(n)
    }

    /// Current token count stored for a request.
    pub fn tokens_of(&self, id: RequestId) -> Option<u32> {
        self.tables.get(&id).map(|t| t.tokens)
    }

    /// Page list of a request (used by the runtime engine's slot mapping).
    pub fn pages_of(&self, id: RequestId) -> Option<&[u32]> {
        self.tables.get(&id).map(|t| t.pages.as_slice())
    }

    /// Number of live requests.
    pub fn live_requests(&self) -> usize {
        self.tables.len()
    }

    /// Internal-fragmentation ratio: wasted tail slots / allocated slots.
    pub fn fragmentation(&self) -> f64 {
        let allocated: u64 = self
            .tables
            .values()
            .map(|t| t.pages.len() as u64 * self.config.page_size as u64)
            .sum();
        if allocated == 0 {
            return 0.0;
        }
        let used: u64 = self.tables.values().map(|t| t.tokens as u64).sum();
        (allocated - used) as f64 / allocated as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;

    fn cache(pages: u32) -> KvCache {
        KvCache::new(KvConfig { page_size: 16, total_pages: pages })
    }

    #[test]
    fn allocate_rounds_up_to_pages() {
        let mut kv = cache(10);
        kv.allocate(RequestId(1), 17).unwrap();
        assert_eq!(kv.used_pages(), 2);
        assert_eq!(kv.tokens_of(RequestId(1)), Some(17));
    }

    #[test]
    fn oom_is_reported_not_panicked() {
        let mut kv = cache(2);
        let err = kv.allocate(RequestId(1), 100).unwrap_err();
        assert!(matches!(err, KvError::OutOfMemory { .. }));
        assert_eq!(kv.used_pages(), 0);
    }

    #[test]
    fn grow_allocates_only_on_page_boundary() {
        let mut kv = cache(10);
        kv.allocate(RequestId(1), 16).unwrap();
        assert_eq!(kv.used_pages(), 1);
        kv.grow(RequestId(1), 1).unwrap(); // 17 tokens → 2 pages
        assert_eq!(kv.used_pages(), 2);
        for _ in 0..15 {
            kv.grow(RequestId(1), 1).unwrap(); // fill page 2, no new page
        }
        assert_eq!(kv.used_pages(), 2);
        kv.grow(RequestId(1), 1).unwrap();
        assert_eq!(kv.used_pages(), 3);
    }

    #[test]
    fn grow_bulk_matches_token_by_token_grow() {
        // Same pages, same order, same OOM boundary as k single grows.
        let mut bulk = cache(8);
        let mut serial = cache(8);
        for kv in [&mut bulk, &mut serial] {
            kv.allocate(RequestId(1), 20).unwrap();
        }
        let added = bulk.grow_bulk(RequestId(1), 75).unwrap();
        for _ in 0..75 {
            serial.grow(RequestId(1), 1).unwrap();
        }
        assert_eq!(added, 4); // 20 → 95 tokens: 2 → 6 pages
        assert_eq!(bulk.used_pages(), serial.used_pages());
        assert_eq!(bulk.tokens_of(RequestId(1)), serial.tokens_of(RequestId(1)));
        assert_eq!(bulk.pages_of(RequestId(1)), serial.pages_of(RequestId(1)));
        // OOM is all-or-nothing: 95 → 129 tokens needs 9 pages total.
        let before = bulk.free_pages();
        assert!(matches!(bulk.grow_bulk(RequestId(1), 34), Err(KvError::OutOfMemory { .. })));
        assert_eq!(bulk.free_pages(), before);
        assert_eq!(bulk.tokens_of(RequestId(1)), Some(95));
    }

    #[test]
    fn release_returns_pages() {
        let mut kv = cache(4);
        kv.allocate(RequestId(1), 64).unwrap();
        assert_eq!(kv.free_pages(), 0);
        let freed = kv.release(RequestId(1)).unwrap();
        assert_eq!(freed, 4);
        assert_eq!(kv.free_pages(), 4);
        assert!(kv.release(RequestId(1)).is_err());
    }

    #[test]
    fn double_allocate_rejected() {
        let mut kv = cache(4);
        kv.allocate(RequestId(1), 8).unwrap();
        assert!(matches!(kv.allocate(RequestId(1), 8), Err(KvError::AlreadyAllocated(_))));
    }

    #[test]
    fn can_grow_matches_grow() {
        let mut kv = cache(2);
        kv.allocate(RequestId(1), 16).unwrap();
        assert!(kv.can_grow(16, 16));
        assert!(!kv.can_grow(16, 17));
    }

    #[test]
    fn fragmentation_counts_tail_waste() {
        let mut kv = cache(10);
        kv.allocate(RequestId(1), 8).unwrap(); // 1 page, 8/16 used
        assert!((kv.fragmentation() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn reservation_gates_allocation_without_touching_live_tables() {
        let mut kv = cache(10);
        kv.allocate(RequestId(1), 64).unwrap(); // 4 pages
        kv.set_reserved_pages(4);
        assert_eq!(kv.free_pages(), 2, "6 physically free minus 4 reserved");
        assert_eq!(kv.used_pages(), 4, "usage accounting ignores the reservation");
        assert_eq!(kv.free_tokens(), 2 * 16);
        // Allocation is bounded by the effective headroom...
        assert!(matches!(kv.allocate(RequestId(2), 48), Err(KvError::OutOfMemory { .. })));
        kv.allocate(RequestId(2), 32).unwrap();
        assert_eq!(kv.free_pages(), 0);
        // ...growth too, and a release still returns pages to the stack.
        assert!(matches!(kv.grow_bulk(RequestId(2), 1), Err(KvError::OutOfMemory { .. })));
        assert!(!kv.can_grow(32, 1));
        kv.release(RequestId(1)).unwrap();
        assert_eq!(kv.free_pages(), 4);
        // Over-reservation saturates to zero headroom instead of wrapping.
        kv.set_reserved_pages(100);
        assert_eq!(kv.reserved_pages(), 10);
        assert_eq!(kv.free_pages(), 0);
        // Lifting the reservation restores the full pool.
        kv.set_reserved_pages(0);
        assert_eq!(kv.free_pages(), 8);
    }

    #[test]
    fn prop_no_page_leak_or_double_free() {
        // Random alloc/grow/release sequences: pages are conserved and
        // no page is ever owned twice.
        check("kv conservation", 128, |rng| {
            let total = 64;
            let mut kv = cache(total);
            let mut live: Vec<RequestId> = Vec::new();
            let mut next = 0u64;
            for _ in 0..200 {
                match rng.below(3) {
                    0 => {
                        let id = RequestId(next);
                        next += 1;
                        let toks = rng.range(1, 100) as u32;
                        if kv.allocate(id, toks).is_ok() {
                            live.push(id);
                        }
                    }
                    1 if !live.is_empty() => {
                        let id = live[rng.below(live.len() as u64) as usize];
                        let _ = kv.grow(id, rng.range(1, 40) as u32);
                    }
                    2 if !live.is_empty() => {
                        let idx = rng.below(live.len() as u64) as usize;
                        let id = live.swap_remove(idx);
                        kv.release(id).unwrap();
                    }
                    _ => {}
                }
                // Invariant: used + free == total.
                assert_eq!(kv.used_pages() + kv.free_pages(), total);
                // Invariant: every live table's pages are within range and
                // sum of table pages == used.
                let table_pages: u32 =
                    live.iter().map(|id| kv.pages_of(*id).unwrap().len() as u32).sum();
                assert_eq!(table_pages, kv.used_pages());
            }
            for id in live {
                kv.release(id).unwrap();
            }
            assert_eq!(kv.free_pages(), total);
        });
    }
}
