//! Typed stub of the `xla-rs` PJRT API surface consumed by
//! `equinox::runtime::pjrt`. The offline build image has no XLA
//! toolchain, so every entry point that would need one fails cleanly at
//! **client creation** — the single choke point the runtime layer
//! already routes through (`Runtime::cpu()`); artifact-gated tests skip
//! long before reaching it. Data-only constructors (literals, shapes)
//! work, so code handling them typechecks and unit-tests. Swap this for
//! the real bindings by editing one line in the root `Cargo.toml`.

use std::fmt;

/// Stub error: every fallible PJRT call reports the runtime is absent.
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable(what: &str) -> Error {
    Error(format!("{what} requires the real XLA/PJRT bindings (offline stub build)"))
}

pub type Result<T> = std::result::Result<T, Error>;

/// Host-side element storage, one variant per supported dtype.
#[derive(Debug, Clone)]
enum Storage {
    F32(Vec<f32>),
    I32(Vec<i32>),
    I64(Vec<i64>),
}

impl Storage {
    fn len(&self) -> usize {
        match self {
            Storage::F32(v) => v.len(),
            Storage::I32(v) => v.len(),
            Storage::I64(v) => v.len(),
        }
    }

    fn kind(&self) -> &'static str {
        match self {
            Storage::F32(_) => "f32",
            Storage::I32(_) => "i32",
            Storage::I64(_) => "i64",
        }
    }
}

/// Element types a [`Literal`] can carry.
pub trait NativeType: Copy + Sized + 'static {
    fn store(v: &[Self]) -> Storage;
    fn load(s: &Storage) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn store(v: &[Self]) -> Storage {
        Storage::F32(v.to_vec())
    }

    fn load(s: &Storage) -> Option<Vec<Self>> {
        match s {
            Storage::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn store(v: &[Self]) -> Storage {
        Storage::I32(v.to_vec())
    }

    fn load(s: &Storage) -> Option<Vec<Self>> {
        match s {
            Storage::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i64 {
    fn store(v: &[Self]) -> Storage {
        Storage::I64(v.to_vec())
    }

    fn load(s: &Storage) -> Option<Vec<Self>> {
        match s {
            Storage::I64(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// Host-side tensor. The stub stores real data so literal construction,
/// reshape, and readback round-trip; only device execution is absent.
#[derive(Debug, Clone)]
pub struct Literal {
    data: Storage,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { data: T::store(v), dims: vec![v.len() as i64] }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error(format!("reshape {:?} onto {} elements", dims, self.data.len())));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(unavailable("tuple decomposition of device results"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::load(&self.data)
            .ok_or_else(|| Error(format!("literal holds {}, asked for another dtype", self.data.kind())))
    }
}

/// Parsed HLO module (stub: parsing is deferred to compile time, which
/// never arrives without a client).
pub struct HloModuleProto {
    _path: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        // Reading the artifact is host-side and works; anything beyond
        // requires the real bindings, reported at compile().
        match std::fs::metadata(path) {
            Ok(_) => Ok(HloModuleProto { _path: path.to_string() }),
            Err(e) => Err(Error(format!("reading HLO text {path}: {e}"))),
        }
    }
}

/// Computation wrapper.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT client — creation is the stub's single failure choke point.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compilation"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with literal-like inputs; per-device × per-output buffers.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("execution"))
    }
}

/// Device buffer handle.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("device-to-host transfer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literals_roundtrip_on_host() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 3]).is_err());
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn client_creation_is_the_choke_point() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("stub"));
    }
}
