//! Offline subset of the `anyhow` error-handling crate, API-compatible
//! with the usage in this repository: `Result`, `Error`, the `Context`
//! extension trait on `Result`/`Option`, and the `anyhow!`/`bail!`/
//! `ensure!` macros. The registry is unavailable in the build image, so
//! this vendored shim keeps the crate self-contained; swap it for the
//! real `anyhow` by editing one line in the root `Cargo.toml`.

use std::fmt;

/// `Result` specialised to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error: an outermost message plus the chain of causes.
/// Deliberately does NOT implement `std::error::Error`, exactly like the
/// real crate — that is what allows the blanket `From<E: std::error::Error>`
/// conversion to coexist with the reflexive `From<Error>`.
pub struct Error {
    /// `chain[0]` is the outermost context; later entries are causes.
    chain: Vec<String>,
}

impl Error {
    /// Construct from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    fn wrap<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message (what `Display` shows).
    pub fn to_string_outer(&self) -> String {
        self.chain.first().cloned().unwrap_or_default()
    }

    /// The cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the full chain on one line.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.chain.split_first() {
            None => Ok(()),
            Some((head, rest)) => {
                write!(f, "{head}")?;
                if !rest.is_empty() {
                    write!(f, "\n\nCaused by:")?;
                    for c in rest {
                        write!(f, "\n    {c}")?;
                    }
                }
                Ok(())
            }
        }
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Attach context to fallible values, promoting them to `anyhow::Result`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).wrap(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).wrap(f()))
    }
}

// No overlap with the impl above: `Error` does not implement
// `std::error::Error` (see the type's docs).
impl<T> Context<T> for std::result::Result<T, Error> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.wrap(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => { $crate::Error::msg(format!($msg)) };
    ($fmt:expr, $($arg:tt)*) => { $crate::Error::msg(format!($fmt, $($arg)*)) };
    ($msg:expr $(,)?) => { $crate::Error::msg($msg) };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) { $crate::bail!(concat!("condition failed: ", stringify!($cond))) }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) { $crate::bail!($($arg)*) }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::result::Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::Other, "disk on fire"))
    }

    #[test]
    fn context_wraps_and_displays() {
        let e = io_err().context("reading manifest").unwrap_err();
        assert_eq!(e.to_string(), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: disk on fire");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn option_context_and_double_question_mark() {
        fn inner() -> Result<u32> {
            let v: Result<Result<u32>, std::io::Error> = Ok(Ok(7));
            v.context("outer")?
        }
        assert_eq!(inner().unwrap(), 7);
        let e: Result<u32> = None.context("missing");
        assert_eq!(e.unwrap_err().to_string(), "missing");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("unlucky {x}");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(f(3).unwrap_err().to_string(), "unlucky 3");
        assert_eq!(f(11).unwrap_err().to_string(), "x too big: 11");
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
    }
}
