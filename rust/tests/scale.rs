//! Scale tier: the million-tenant storage layer's load-bearing contracts.
//!
//! 1. **Zero drift** — the generic schedulers instantiated over dense
//!    `ClientSlab` storage (production) and `BTreeMap` storage
//!    (reference) produce bit-identical end-to-end fingerprints on every
//!    adversarial scenario: storage is a pure performance choice and may
//!    never change a decision. (`tests/properties.rs` checks the same
//!    contract at the pick-sequence level.)
//! 2. **Population smoke** — 100k tenants enqueue/drain through the
//!    indexed schedulers under a wall-clock tripwire, and the
//!    `with_clients` knob generates sane 20k-tenant traces.
//! 3. **Allocation audit** — a counting global allocator proves warmed
//!    per-tenant state (slab probes, admission charges) allocates
//!    nothing, and bounds the engine's steady-state per-step allocator
//!    traffic (residual churn is ordered-index/KV tree nodes, documented
//!    in EXPERIMENTS.md §Scale).

use equinox::core::{ClientId, ClientSlab, Request, RequestId};
use equinox::exp::{make_pred, PredKind};
use equinox::harness::{self, derive_seed};
use equinox::predictor::PerfMap;
use equinox::sched::{
    Actuals, EquinoxSched, HfParams, HolisticCounters, MapEquinox, MapRpm, MapVtc, Rpm, Scheduler,
    Vtc,
};
use equinox::sim::{step_once, RunState, SimConfig, Simulation};
use equinox::workload::{adversarial, generate, Scenario, Trace};
use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::time::{Duration, Instant};

// ---- counting allocator -------------------------------------------------

/// Per-thread allocation counter: tests measure deltas on their own
/// thread, so the parallel test runner cannot pollute a measurement.
/// Const-init keeps the TLS access itself allocation-free.
struct CountingAlloc;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static COUNTING_ALLOC: CountingAlloc = CountingAlloc;

fn alloc_count() -> u64 {
    ALLOCS.with(|c| c.get())
}

// ---- helpers ------------------------------------------------------------

fn truncated(trace: &Trace, n: usize) -> Trace {
    Trace { requests: trace.requests.iter().take(n).cloned().collect(), horizon: trace.horizon }
}

fn scale_request(id: u64, client: u32) -> Request {
    let mut r = Request::new(RequestId(id), ClientId(client), 32, 32, 0.0);
    r.predicted_output_tokens = 32;
    r.predicted_latency = 1.0;
    r.predicted_tps = 1000.0;
    r.predicted_gpu_util = 0.8;
    r
}

// ---- zero drift ---------------------------------------------------------

/// Acceptance bar: slab-backed and BTreeMap-backed schedulers are
/// bit-identical (fingerprint AND digest) through the full engine on
/// every adversarial scenario, for every counter-based scheduler.
#[test]
fn slab_and_btreemap_storage_produce_identical_fingerprints() {
    for sc in adversarial::registry() {
        let seed = derive_seed(42, sc.name, "storage-family");
        // Truncated quick traces keep the 14-scenario × 4-pair matrix
        // inside the tier-1 time budget; every code path this PR touches
        // (admission, lifts, picks, completion, export) fires well before
        // 220 arrivals.
        let trace = truncated(&sc.trace(true, seed), 220);
        let pairs: Vec<(Box<dyn Scheduler>, Box<dyn Scheduler>, PredKind, &str)> = vec![
            (Box::new(Vtc::new()), Box::new(MapVtc::for_family()), PredKind::Oracle, "vtc"),
            (
                Box::new(Vtc::with_predictions()),
                Box::new(MapVtc::for_family_with_predictions()),
                PredKind::Mope,
                "vtc-pred",
            ),
            (
                Box::new(EquinoxSched::default_params(2000.0)),
                Box::new(MapEquinox::for_family(HfParams::default(), 2000.0)),
                PredKind::Mope,
                "equinox",
            ),
            (
                Box::new(Rpm::new(120, 60.0)),
                Box::new(MapRpm::for_family(120, 60.0)),
                PredKind::Oracle,
                "rpm",
            ),
        ];
        for (mut slab, mut btree, pred, label) in pairs {
            let run = |sched: &mut dyn Scheduler| {
                let mut p = make_pred(pred, seed);
                let mut sim = Simulation::new(SimConfig::a100_7b_vllm(), sched, p.as_mut());
                sim.run(&trace)
            };
            let a = run(slab.as_mut());
            let b = run(btree.as_mut());
            assert_eq!(
                harness::fingerprint(&a),
                harness::fingerprint(&b),
                "{}/{label}: slab vs btreemap storage drifted",
                sc.name
            );
            assert_eq!(harness::digest(&a), harness::digest(&b), "{}/{label}", sc.name);
        }
    }
}

// ---- population smoke ---------------------------------------------------

/// 100k tenants, one queued request each, enqueue → drain through the
/// indexed schedulers. The wall-clock tripwire is generous for a debug
/// build; a regression to linear scans or per-op allocation in the
/// per-tenant state blows straight past it.
#[test]
fn hundred_k_tenant_scheduler_smoke() {
    let n: u32 = 100_000;
    let start = Instant::now();
    let make: [fn() -> Box<dyn Scheduler>; 2] = [
        || Box::new(Vtc::new()),
        || Box::new(EquinoxSched::default_params(2000.0)),
    ];
    for mk in make {
        let mut sched = mk();
        for c in 0..n {
            sched.enqueue(scale_request(c as u64, c), 0.0);
        }
        assert_eq!(sched.queue_len(), n as usize);
        assert_eq!(sched.queued_clients().len(), n as usize);
        let actuals = Actuals { latency: 1.0, gpu_util: 0.8, tps: 1000.0, output_tokens: 32 };
        let mut drained = 0usize;
        while let Some(r) = sched.pick(1.0, &mut |_| true) {
            sched.on_complete(&r, &actuals, 2.0);
            drained += 1;
        }
        assert_eq!(drained, n as usize, "{}", sched.name());
        assert!(sched.queued_clients().is_empty(), "{}", sched.name());
    }
    assert!(
        start.elapsed() < Duration::from_secs(120),
        "100k-tenant smoke too slow: {:?}",
        start.elapsed()
    );
}

/// The `with_clients` population knob generates sane large traces: the
/// resized heavy-hitter scenario materialises (nearly) every tenant,
/// stays arrival-sorted, and carries the per-spec weights.
#[test]
fn with_clients_generates_sane_20k_tenant_trace() {
    let sc = Scenario::heavy_hitter(9, 10.0).with_clients(20_000);
    let trace = generate(&sc, 7);
    assert!(!trace.is_empty());
    for w in trace.requests.windows(2) {
        assert!(w[0].arrival <= w[1].arrival, "arrivals out of order");
    }
    // Poisson at the ~2-requests-per-tenant floor leaves a ~13% silent
    // tail; the bulk of the population must still materialise.
    assert!(
        trace.num_clients() > 15_000,
        "only {} of 20000 tenants materialised",
        trace.num_clients()
    );
}

// ---- allocation audit ---------------------------------------------------

/// Warmed per-tenant state is allocation-free on the hot ops: slab
/// probes/bumps, membership churn on existing slots, and the full
/// admission charge (UFC + RFC) for a known tenant.
#[test]
fn warmed_dense_state_hot_ops_are_allocation_free() {
    let mut slab: ClientSlab<u64> = ClientSlab::new();
    for c in 0..4096u32 {
        *slab.or_default(ClientId(c)) += 1;
    }
    let before = alloc_count();
    for c in 0..4096u32 {
        *slab.or_default(ClientId(c)) += 1;
    }
    // take + re-touch: membership churn reuses the retired slot storage.
    let taken = slab.take(ClientId(7)).unwrap_or(0);
    *slab.or_default(ClientId(7)) = taken;
    let mut sum = 0u64;
    slab.for_each(&mut |_, v| sum += *v);
    assert_eq!(alloc_count() - before, 0, "warmed slab ops must not allocate");
    assert!(sum > 0);

    let mut hc: HolisticCounters = HolisticCounters::new(HfParams::default());
    for c in 0..4096u32 {
        hc.touch(ClientId(c), 1.0);
    }
    let mut req = scale_request(1, 0);
    let before = alloc_count();
    for c in 0..4096u32 {
        req.client = ClientId(c);
        hc.charge_admission(&req, 1.0, 1000.0);
    }
    assert_eq!(alloc_count() - before, 0, "warmed admission charge must not allocate");
}

/// Steady-state engine stepping stays within a tight per-step allocation
/// budget after warmup. The per-tenant structures (latency slabs,
/// service curves, counter slabs, preemption scratch) contribute zero;
/// the residual traffic is node churn in the ordered score index /
/// KV-table trees plus amortised timeline growth — bounded and
/// population-independent (EXPERIMENTS.md §Scale records the measured
/// figure).
#[test]
fn steady_state_stepping_allocation_budget() {
    let trace = generate(&Scenario::heavy_hitter(3, 20.0), 11);
    let cfg = SimConfig::a100_7b_vllm();
    let mut sched = EquinoxSched::default_params(2000.0);
    let mut pred = make_pred(PredKind::Oracle, 11);
    let mut perfmap = PerfMap::default_a100_7b();
    let mut st = RunState::start(&cfg, &trace);
    let mut warm = 0u64;
    while warm < 400 && step_once(&cfg, &mut sched, pred.as_mut(), &mut perfmap, &mut st, None) {
        warm += 1;
    }
    assert_eq!(warm, 400, "trace drained during warmup; grow the scenario");
    let before = alloc_count();
    let mut steps = 0u64;
    while steps < 200 && step_once(&cfg, &mut sched, pred.as_mut(), &mut perfmap, &mut st, None) {
        steps += 1;
    }
    assert_eq!(steps, 200, "trace drained during measurement; grow the scenario");
    let per_step = (alloc_count() - before) as f64 / steps as f64;
    // A per-tenant-map regression (BTreeMap node per touch) shows up as
    // hundreds of allocs/step; the legitimate residual is O(1) tree-node
    // and amortised-Vec traffic.
    assert!(
        per_step <= 24.0,
        "steady-state stepping allocates {per_step:.1}/step — hot-path regression"
    );
}
