//! Flight-recorder tier: the observability subsystem's load-bearing
//! contracts.
//!
//! 1. **Serial ≡ parallel trace digest** — the merged event stream is
//!    bit-identical across drive modes and thread counts, a strictly
//!    stronger check than the aggregate cluster fingerprint (it covers
//!    every event's time bits, track, sequence number, and payload).
//! 2. **Replay bit-identity** — the same traced cell re-run produces the
//!    identical event vector, not just the identical digest.
//! 3. **Ring-overflow determinism** — with a tiny ring capacity both
//!    drive modes drop the SAME events and report the SAME drop count.
//! 4. **NullRecorder zero cost** — with tracing off (the default), the
//!    warmed steady-state engine step stays strictly within the
//!    `tests/scale.rs` allocation budget; with a TraceRecorder attached
//!    the step allocates no more (the ring is preallocated).
//! 5. **Golden JSONL snapshot** — header + leading events of one quick
//!    cell are pinned; regenerate with `GOLDEN_REGEN=1` after an
//!    intentional schema or behavioural change (tests/golden/README.md).

use equinox::cluster::{run_cluster, ClusterOpts, DriveMode, Fleet, RouterKind};
use equinox::exp::{make_pred, PredKind, SchedKind};
use equinox::harness::cluster::{cluster_trace, SCENARIOS};
use equinox::harness::derive_seed;
use equinox::harness::trace::{run_traced_cell, serial_parallel_trace_digests};
use equinox::obs::{TraceCfg, TraceRecorder};
use equinox::predictor::PerfMap;
use equinox::sched::EquinoxSched;
use equinox::sim::{step_once, RunState, SimConfig};
use equinox::workload::{generate, Scenario};
use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

// ---- counting allocator (same pattern as tests/scale.rs) ----------------

struct CountingAlloc;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static COUNTING_ALLOC: CountingAlloc = CountingAlloc;

fn alloc_count() -> u64 {
    ALLOCS.with(|c| c.get())
}

// ---- serial ≡ parallel --------------------------------------------------

/// Acceptance bar: every adversarial cluster scenario × {RoundRobin,
/// FairShare} × {2, 8} worker threads produces the identical trace
/// digest under serial and parallel drives.
#[test]
fn trace_digest_is_drive_mode_invariant() {
    for scenario in SCENARIOS {
        for router in [RouterKind::RoundRobin, RouterKind::FairShare] {
            for threads in [2usize, 8] {
                let (s, p) = serial_parallel_trace_digests(
                    scenario,
                    Fleet::homogeneous(4),
                    router,
                    threads,
                    true,
                    42,
                );
                assert_eq!(
                    s, p,
                    "{scenario}/{}/threads{threads}: trace digest diverged across drives",
                    router.label()
                );
            }
        }
    }
}

/// The heterogeneous fleet (capacity + bandwidth asymmetry) under the
/// fairness router — the drive-sensitive configuration — also matches.
#[test]
fn hetero_fleet_trace_digest_is_drive_mode_invariant() {
    let (s, p) = serial_parallel_trace_digests(
        "heavy_hitter",
        Fleet::hetero(),
        RouterKind::FairShare,
        2,
        true,
        42,
    );
    assert_eq!(s, p);
}

// ---- replay bit-identity ------------------------------------------------

/// Two runs of the same traced cell produce the identical event VECTOR —
/// every time, track, sequence number, and payload — not merely a
/// colliding digest.
#[test]
fn traced_replay_is_bit_identical_eventwise() {
    let a = run_traced_cell(
        "flash_crowd",
        Fleet::homogeneous(4),
        RouterKind::FairShare,
        DriveMode::Serial,
        true,
        42,
    );
    let b = run_traced_cell(
        "flash_crowd",
        Fleet::homogeneous(4),
        RouterKind::FairShare,
        DriveMode::Serial,
        true,
        42,
    );
    assert_eq!(a.log.events.len(), b.log.events.len());
    assert_eq!(a.log.events, b.log.events, "replay produced different events");
    assert_eq!(a.log.dropped, b.log.dropped);
    assert_eq!(a.trace_digest(), b.trace_digest());
}

// ---- ring overflow ------------------------------------------------------

/// A deliberately tiny ring overflows in every track; both drive modes
/// must overwrite the SAME oldest events and report the SAME cumulative
/// drop count — overflow is part of the deterministic contract, not an
/// escape hatch from it.
#[test]
fn ring_overflow_is_drive_mode_invariant() {
    let seed = derive_seed(42, "heavy_hitter", "overflow");
    let fleet = Fleet::homogeneous(4);
    let trace = cluster_trace("heavy_hitter", fleet.len(), true, seed);
    let run = |drive: DriveMode| {
        let opts = ClusterOpts::new(seed)
            .with_drive(drive)
            .with_trace(TraceCfg { capacity: 64 });
        run_cluster(
            fleet.clone(),
            RouterKind::FairShare.make(),
            SchedKind::Equinox,
            PredKind::Mope,
            &trace,
            &opts,
        )
        .trace
        .expect("tracing enabled")
    };
    let s = run(DriveMode::Serial);
    let p = run(DriveMode::Parallel { threads: 2 });
    assert!(s.dropped > 0, "capacity 64 must overflow on this cell");
    assert_eq!(s.dropped, p.dropped, "drop counts diverged across drives");
    assert_eq!(s.events, p.events, "surviving events diverged across drives");
    assert_eq!(s.digest(), p.digest());
}

// ---- allocation audit ---------------------------------------------------

fn stepping_allocs_per_step(rec: Option<TraceRecorder>) -> f64 {
    let trace = generate(&Scenario::heavy_hitter(3, 20.0), 11);
    let cfg = SimConfig::a100_7b_vllm();
    let mut sched = EquinoxSched::default_params(2000.0);
    let mut pred = make_pred(PredKind::Oracle, 11);
    let mut perfmap = PerfMap::default_a100_7b();
    let mut st = RunState::start(&cfg, &trace);
    if let Some(r) = rec {
        st.set_recorder(Box::new(r));
    }
    let mut warm = 0u64;
    while warm < 400 && step_once(&cfg, &mut sched, pred.as_mut(), &mut perfmap, &mut st, None) {
        warm += 1;
    }
    assert_eq!(warm, 400, "trace drained during warmup; grow the scenario");
    let before = alloc_count();
    let mut steps = 0u64;
    while steps < 200 && step_once(&cfg, &mut sched, pred.as_mut(), &mut perfmap, &mut st, None) {
        steps += 1;
    }
    assert_eq!(steps, 200, "trace drained during measurement; grow the scenario");
    (alloc_count() - before) as f64 / steps as f64
}

/// With the default NullRecorder, warmed steady-state stepping stays
/// strictly within the `tests/scale.rs` budget — the recorder hook adds
/// zero allocator traffic to the hot path.
#[test]
fn null_recorder_keeps_the_steady_state_allocation_budget() {
    let per_step = stepping_allocs_per_step(None);
    assert!(
        per_step <= 24.0,
        "steady-state stepping with NullRecorder allocates {per_step:.1}/step"
    );
}

/// A live TraceRecorder allocates once (at construction) and never on
/// the step path: the same budget holds with recording on.
#[test]
fn trace_recorder_steps_within_the_same_budget() {
    let per_step = stepping_allocs_per_step(Some(TraceRecorder::new(0, 1 << 18)));
    assert!(
        per_step <= 24.0,
        "steady-state stepping with TraceRecorder allocates {per_step:.1}/step"
    );
}

// ---- single-engine traced run -------------------------------------------

/// `Simulation::run_traced` — the single-engine (no cluster) entry point
/// — is also a pure observer: identical `SimResult` fingerprint with and
/// without the recorder, and the merged stream covers the lifecycle.
#[test]
fn single_engine_run_traced_is_a_pure_observer() {
    let trace = generate(&Scenario::heavy_hitter(3, 20.0), 7);
    let run_plain = || {
        let mut sched = EquinoxSched::default_params(2000.0);
        let mut pred = make_pred(PredKind::Oracle, 7);
        let mut sim =
            equinox::sim::Simulation::new(SimConfig::a100_7b_vllm(), &mut sched, pred.as_mut());
        sim.run(&trace)
    };
    let plain = run_plain();
    let mut sched = EquinoxSched::default_params(2000.0);
    let mut pred = make_pred(PredKind::Oracle, 7);
    let mut sim =
        equinox::sim::Simulation::new(SimConfig::a100_7b_vllm(), &mut sched, pred.as_mut());
    let (traced, events, dropped) = sim.run_traced(&trace, 1 << 18);
    assert_eq!(
        equinox::harness::fingerprint(&plain),
        equinox::harness::fingerprint(&traced),
        "recorder perturbed the engine"
    );
    assert_eq!(dropped, 0, "ring overflowed on a quick scenario");
    assert!(!events.is_empty());
    // Canonical (t, seq) order: time non-decreasing, seq breaking ties
    // strictly. (Seq alone is NOT globally monotone: an Arrive is stamped
    // at its arrival time, which can precede already-recorded events.)
    for w in events.windows(2) {
        assert!(w[0].t < w[1].t || (w[0].t == w[1].t && w[0].seq < w[1].seq));
    }
    let finishes =
        events.iter().filter(|e| matches!(e.kind, equinox::obs::EventKind::Finish { .. })).count();
    assert_eq!(finishes, plain.finished, "one Finish event per completed request");
}

// ---- golden snapshot ----------------------------------------------------

/// Header + leading 64 event lines of one quick traced cell, pinned.
/// The header embeds the full-stream digest, so drift anywhere in the
/// run — not just the head — fails the comparison.
/// `GOLDEN_REGEN=1 cargo test -q golden_trace` rewrites it after an
/// intentional change (tests/golden/README.md; absent file = not yet
/// seeded on this platform).
#[test]
fn golden_trace_jsonl_matches_committed() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden/trace.jsonl");
    let cell = run_traced_cell(
        "balanced_load",
        Fleet::solo(),
        RouterKind::RoundRobin,
        DriveMode::Serial,
        true,
        42,
    );
    let jsonl = equinox::obs::export::to_jsonl(&cell.log);
    let mut snapshot: String =
        jsonl.lines().take(65).collect::<Vec<_>>().join("\n");
    snapshot.push('\n');
    if std::env::var("GOLDEN_REGEN").as_deref() == Ok("1") {
        std::fs::create_dir_all(std::path::Path::new(path).parent().unwrap()).unwrap();
        std::fs::write(path, &snapshot).unwrap();
        eprintln!("golden regenerated at {path}");
        return;
    }
    let Ok(want) = std::fs::read_to_string(path) else {
        eprintln!(
            "golden trace absent at {path} — run `GOLDEN_REGEN=1 cargo test -q \
             golden_trace` once on this platform to create it"
        );
        return;
    };
    assert_eq!(
        want, snapshot,
        "golden trace drift (regen with GOLDEN_REGEN=1 if intentional)"
    );
}
