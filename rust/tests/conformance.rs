//! Tier-1 conformance: the scheduler × adversarial-scenario × step-mode
//! matrix with machine-checked invariants (see `equinox::harness` and
//! EXPERIMENTS.md §Conformance matrix). The matrix is split into one
//! test per scenario group so the test harness runs groups in parallel;
//! every group covers ALL schedulers × BOTH step modes, with the macro
//! leg replayed for the deterministic-replay invariant.

use equinox::harness::{
    self, broken, derive_seed, fingerprint, ConformanceOpts, MODES, SCHEDULERS,
};
use equinox::sim::{SimConfig, StepMode};
use equinox::workload::adversarial;

fn conform(names: &[&str]) {
    let opts = ConformanceOpts::default();
    for &name in names {
        let sc = adversarial::find(name).unwrap_or_else(|| panic!("unknown scenario {name}"));
        let cells = harness::run_scenario_cells(&sc, &opts, &MODES);
        assert_eq!(cells.len(), SCHEDULERS.len() * MODES.len(), "{name}: cell count");
        for c in &cells {
            assert!(
                c.passed(),
                "{}: invariant violations: {:?} (notes: {:?})",
                c.key(),
                c.violations,
                c.notes
            );
            assert_eq!(c.finished, c.total, "{}: must drain", c.key());
        }
        // The macro engine must actually macro-step somewhere in the
        // scenario sweep — otherwise the mode axis tests nothing.
        assert!(
            cells.iter().filter(|c| c.mode == "macro").any(|c| c.macro_steps > 0),
            "{name}: no scheduler took a macro-step"
        );
    }
}

#[test]
fn paper_scenarios_conform() {
    conform(&["balanced_load", "stochastic_arrivals", "equal_tokens"]);
}

#[test]
fn overload_scenarios_conform() {
    conform(&["constant_overload", "dynamic_load"]);
}

#[test]
fn hostile_rate_scenarios_conform() {
    conform(&["heavy_hitter", "flash_crowd"]);
}

#[test]
fn temporal_scenarios_conform() {
    conform(&["diurnal", "tenant_churn"]);
}

#[test]
fn heterogeneous_scenarios_conform() {
    conform(&["weighted_tiers", "prefill_decode_duel"]);
}

#[test]
fn trace_like_scenarios_conform() {
    conform(&["multi_turn", "trace_mix", "mixed_tenants"]);
}

/// Satellite: `generate(scenario, seed)` is bit-identical across two
/// invocations for every registered scenario, under the per-(scenario,
/// scheduler) derived seeds the matrix actually uses — so matrix cells
/// are reproducible AND independent.
#[test]
fn trace_generation_is_bit_identical_per_cell() {
    let mut seeds = std::collections::BTreeSet::new();
    for sc in adversarial::registry() {
        for kind in SCHEDULERS {
            let seed = derive_seed(42, sc.name, &kind.label());
            assert!(seeds.insert(seed), "{}/{}: seed collision", sc.name, kind.label());
            let a = sc.trace(true, seed);
            let b = sc.trace(true, seed);
            assert_eq!(a.len(), b.len(), "{}", sc.name);
            assert_eq!(a.horizon.to_bits(), b.horizon.to_bits(), "{}", sc.name);
            for (x, y) in a.requests.iter().zip(b.requests.iter()) {
                assert_eq!(x.arrival.to_bits(), y.arrival.to_bits(), "{}", sc.name);
                assert_eq!(x.client, y.client, "{}", sc.name);
                assert_eq!(x.input_tokens, y.input_tokens, "{}", sc.name);
                assert_eq!(x.true_output_tokens, y.true_output_tokens, "{}", sc.name);
            }
        }
    }
}

/// Satellite: a full `Simulation::run` is bit-identical across two
/// invocations for every scheduler (micro mode here; the macro replay is
/// asserted inside every matrix cell above).
#[test]
fn full_runs_are_bit_identical_for_every_scheduler() {
    use equinox::exp::run_sim_stepped;
    let sc = adversarial::find("flash_crowd").unwrap();
    let cfg = SimConfig::a100_7b_vllm();
    for kind in SCHEDULERS {
        let seed = derive_seed(7, sc.name, &kind.label());
        let trace = sc.trace(true, seed);
        let pred = if kind == equinox::exp::SchedKind::Equinox {
            equinox::exp::PredKind::Mope
        } else {
            equinox::exp::PredKind::Oracle
        };
        let a = run_sim_stepped(&cfg, StepMode::Micro, kind, pred, &trace, seed);
        let b = run_sim_stepped(&cfg, StepMode::Micro, kind, pred, &trace, seed);
        assert_eq!(
            fingerprint(&a),
            fingerprint(&b),
            "{}: micro replay diverged",
            kind.label()
        );
    }
}

/// Satellite (weight plumbing): ω_f flows from `ClientSpec::with_weight`
/// through the generated trace into the admission charges, so under
/// sustained overload a fair scheduler delivers service ∝ ω. Run with
/// drain off — after a full drain every client receives its whole demand
/// and the ratio is washed out by conservation.
#[test]
fn weighted_clients_receive_proportional_service() {
    use equinox::core::ClientId;
    use equinox::exp::{run_sim, PredKind, SchedKind};
    use equinox::workload::{generate, ArrivalProcess, Arrival, ClientSpec, Scenario};

    let mk = |w0: f64| Scenario {
        name: "weighted_duel",
        clients: vec![
            ClientSpec::fixed(Arrival::Deterministic, ArrivalProcess::Constant(10.0), 50, 200)
                .with_weight(w0),
            ClientSpec::fixed(Arrival::Deterministic, ArrivalProcess::Constant(10.0), 50, 200),
        ],
        duration: 30.0,
    };
    let trace = generate(&mk(2.0), 17);
    let mut cfg = SimConfig::a100_7b_vllm();
    cfg.drain = false; // steady-state share, not the drain tail
    let ratio = |kind: SchedKind, pred: PredKind| {
        let res = run_sim(&cfg, kind, pred, &trace, 17);
        let s0 = res.service.total(ClientId(0));
        let s1 = res.service.total(ClientId(1)).max(1e-9);
        s0 / s1
    };
    // VTC: counter equalisation is exactly share ∝ ω.
    let r_vtc = ratio(SchedKind::Vtc, PredKind::Oracle);
    assert!((1.5..=2.6).contains(&r_vtc), "VTC ω=2 share ratio {r_vtc} not ≈2");
    // Equinox: the latency-compensation term discounts the backlogged
    // ω=1 tenant, pulling the ratio below 2 — but the ω=2 tenant must
    // still come out clearly ahead.
    let r_eqx = ratio(SchedKind::Equinox, PredKind::Oracle);
    assert!(r_eqx > 1.15, "Equinox ω=2 share ratio {r_eqx} must exceed 1");
}

/// The harness must actually FAIL on a fairness violation: a strict-
/// priority scheduler under sustained overload starves the victim tenant
/// for the whole co-backlogged stretch, and both the no-starvation and
/// bounded-discrepancy invariants exist to catch exactly that.
#[test]
fn broken_scheduler_is_flagged() {
    let opts = ConformanceOpts::default();
    let verdict = broken::run_strict_priority_fixture(&opts);
    assert!(
        !verdict.passed(),
        "harness failed to flag a strict-priority scheduler: notes {:?}, max_disc {} vs bound {}",
        verdict.notes,
        verdict.max_disc,
        verdict.disc_bound
    );
    assert!(
        verdict
            .violations
            .iter()
            .any(|v| v.starts_with("starvation") || v.starts_with("discrepancy")),
        "violations must name a fairness invariant, got {:?}",
        verdict.violations
    );
}

/// Golden snapshots: committed macro-cell digests pin the exact run
/// outcomes; `GOLDEN_REGEN=1 cargo test -q golden` rewrites them after
/// an intentional change (see tests/golden/README.md).
#[test]
fn golden_snapshot_matches_committed() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden/conformance.json");
    let opts = ConformanceOpts::default();
    let cells = harness::run_matrix(&opts, &[StepMode::Macro]);
    for c in &cells {
        assert!(c.passed(), "{}: {:?}", c.key(), c.violations);
    }
    if std::env::var("GOLDEN_REGEN").as_deref() == Ok("1") {
        let doc = harness::golden_from_cells(&cells);
        std::fs::create_dir_all(std::path::Path::new(path).parent().unwrap()).unwrap();
        std::fs::write(path, doc.to_string()).unwrap();
        eprintln!("golden regenerated at {path}");
        return;
    }
    let Ok(text) = std::fs::read_to_string(path) else {
        eprintln!(
            "golden snapshot absent at {path} — run `GOLDEN_REGEN=1 cargo test -q \
             golden_snapshot` once on this platform to create it"
        );
        return;
    };
    let golden = equinox::util::json::Json::parse(&text).expect("golden must parse");
    let diffs = harness::compare_golden(&golden, &cells);
    assert!(
        diffs.is_empty(),
        "golden drift (regen with GOLDEN_REGEN=1 if intentional):\n  {}",
        diffs.join("\n  ")
    );
}
