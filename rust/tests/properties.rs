//! Randomized property tests over coordinator invariants (routing,
//! batching, counter state) using the in-crate `util::check` helper
//! (offline substitute for proptest — see DESIGN.md substitution ledger).

use equinox::core::{ClientId, Request, RequestId};
use equinox::exp::{run_sim, PredKind, SchedKind};
use equinox::sched::{Actuals, EquinoxSched, Fcfs, LinearEquinox, LinearVtc, Scheduler, Vtc};
use equinox::sim::SimConfig;
use equinox::util::check::check;
use equinox::util::rng::Rng;
use equinox::workload::{ClientSpec, Scenario};

fn random_request(rng: &mut Rng, id: u64) -> Request {
    let mut r = Request::new(
        RequestId(id),
        ClientId(rng.below(6) as u32),
        rng.range(1, 768) as u32,
        rng.range(1, 768) as u32,
        rng.f64() * 10.0,
    );
    r.predicted_output_tokens = rng.range(1, 1024) as u32;
    r.predicted_latency = rng.f64() * 10.0;
    r.predicted_tps = rng.range_f64(100.0, 3000.0);
    r.predicted_gpu_util = rng.f64();
    r
}

/// No scheduler may lose or duplicate requests across arbitrary
/// enqueue/pick/requeue/complete interleavings.
#[test]
fn prop_schedulers_conserve_requests() {
    check("request conservation", 96, |rng| {
        let mut scheds: Vec<Box<dyn Scheduler>> = vec![
            Box::new(Fcfs::new()),
            Box::new(Vtc::new()),
            Box::new(Vtc::with_predictions()),
            Box::new(EquinoxSched::default_params(2000.0)),
        ];
        let s = &mut scheds[rng.below(4) as usize];
        let mut in_queue = 0i64;
        let mut in_flight: Vec<Request> = Vec::new();
        let mut completed = 0u64;
        let mut submitted = 0u64;
        for step in 0..300u64 {
            match rng.below(10) {
                0..=4 => {
                    s.enqueue(random_request(rng, step), step as f64);
                    submitted += 1;
                    in_queue += 1;
                }
                5..=6 => {
                    // Random feasibility: sometimes nothing fits.
                    let admit_all = rng.chance(0.8);
                    if let Some(r) = s.pick(step as f64, &mut |_| admit_all) {
                        in_queue -= 1;
                        in_flight.push(r);
                    }
                }
                7 => {
                    if !in_flight.is_empty() {
                        let idx = rng.below(in_flight.len() as u64) as usize;
                        let r = in_flight.swap_remove(idx);
                        s.requeue(r);
                        in_queue += 1;
                    }
                }
                _ => {
                    if !in_flight.is_empty() {
                        let idx = rng.below(in_flight.len() as u64) as usize;
                        let r = in_flight.swap_remove(idx);
                        let out = rng.range(1, 512) as u32;
                        s.on_complete(
                            &r,
                            &Actuals {
                                latency: rng.f64() * 20.0,
                                gpu_util: rng.f64(),
                                tps: rng.range_f64(10.0, 4000.0),
                                output_tokens: out,
                            },
                            step as f64,
                        );
                        completed += 1;
                    }
                }
            }
            assert_eq!(s.queue_len() as i64, in_queue, "queue accounting diverged");
        }
        // Drain.
        while let Some(r) = s.pick(1e6, &mut |_| true) {
            in_queue -= 1;
            in_flight.push(r);
        }
        assert_eq!(in_queue, 0);
        assert_eq!(submitted, in_flight.len() as u64 + completed);
        // All ids distinct (no duplication).
        let mut ids: Vec<u64> = in_flight.iter().map(|r| r.id.0).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), in_flight.len());
    });
}

/// VTC invariant under ASYMMETRIC demand: when one tenant demands ~3× the
/// other, FCFS's service gap grows with the demand ratio while VTC keeps
/// it bounded near the engine's granularity (batch-residency slack). This
/// is the isolation property token-counter fairness actually guarantees;
/// with symmetric demand FCFS's arrival interleaving is already fair and
/// counter-based admission may even oscillate more at iteration level
/// (see EXPERIMENTS.md notes).
#[test]
fn prop_vtc_bounded_discrepancy() {
    check("vtc bounded discrepancy", 8, |rng| {
        let in0 = rng.range(16, 256) as u32;
        let out0 = rng.range(32, 512) as u32;
        let in1 = rng.range(16, 256) as u32;
        let out1 = rng.range(32, 512) as u32;
        // Asymmetric saturating demand: c0 offers ~3× c1.
        let r0 = 4500.0 / out0 as f64;
        let r1 = 1500.0 / out1 as f64;
        let seed = rng.next_u64();
        let run_for = |duration: f64| {
            let sc = Scenario {
                name: "prop",
                clients: vec![
                    ClientSpec::fixed(
                        equinox::workload::Arrival::Deterministic,
                        equinox::workload::arrivals::ArrivalProcess::Constant(r0),
                        in0,
                        out0,
                    ),
                    ClientSpec::fixed(
                        equinox::workload::Arrival::Deterministic,
                        equinox::workload::arrivals::ArrivalProcess::Constant(r1),
                        in1,
                        out1,
                    ),
                ],
                duration,
            };
            let trace = equinox::workload::generate(&sc, seed);
            let cfg =
                SimConfig::a100_7b_vllm().with_host(equinox::sim::HostProfile::SLORA);
            let run = |kind: SchedKind| {
                let res = run_sim(&cfg, kind, PredKind::Oracle, &trace, 1);
                let diffs = res.backlogged_diff_series(ClientId(0), ClientId(1));
                diffs.iter().cloned().fold(0.0, f64::max)
            };
            (run(SchedKind::Vtc), run(SchedKind::Fcfs))
        };
        let (vtc, fcfs) = run_for(60.0);
        if vtc < 1.0 && fcfs < 1.0 {
            return; // no co-backlog at this load shape
        }
        // Under 3:1 demand skew FCFS serves ~proportionally (unfair);
        // VTC must do decisively better, modulo batch-residency slack.
        assert!(
            vtc <= 0.8 * fcfs + 30_000.0,
            "VTC ({vtc}) not better than FCFS ({fcfs}) on shapes {in0}/{out0}, {in1}/{out1}"
        );
    });
}

/// Engine safety: deterministic across runs, requests conserve, KV never
/// leaks (checked indirectly: all requests finish even under random
/// overload shapes).
#[test]
fn prop_engine_completes_random_workloads() {
    check("engine completes", 10, |rng| {
        let sc = Scenario {
            name: "prop",
            clients: (0..rng.range(1, 4))
                .map(|_| {
                    ClientSpec::fixed(
                        equinox::workload::Arrival::Poisson,
                        equinox::workload::arrivals::ArrivalProcess::Constant(
                            rng.range_f64(0.5, 8.0),
                        ),
                        rng.range(1, 512) as u32,
                        rng.range(1, 512) as u32,
                    )
                })
                .collect(),
            duration: 15.0,
        };
        let trace = equinox::workload::generate(&sc, rng.next_u64());
        if trace.is_empty() {
            return;
        }
        for sched in [SchedKind::Fcfs, SchedKind::Equinox] {
            let res = run_sim(&SimConfig::a100_7b_vllm(), sched, PredKind::Mope, &trace, 2);
            assert_eq!(res.finished, trace.len(), "{}", sched.label());
            assert!(res.wall.is_finite() && res.wall > 0.0);
        }
    });
}

/// Differential spec test for the indexed scheduling core: randomized
/// enqueue/pick/requeue/on_complete/on_progress sequences driven through
/// an indexed scheduler (O(log C) `ScoreIndex` pick) and its retained
/// linear-scan reference must produce IDENTICAL pick order — the index is
/// a pure performance structure and may never change a decision. Both
/// sides see the same requests and the same (deterministic) feasibility
/// answers; counter arithmetic is shared code, so any divergence is an
/// index-maintenance bug, not float noise.
#[test]
fn prop_indexed_matches_linear_reference() {
    check("indexed == linear pick order", 48, |rng| {
        let variant = rng.below(3);
        let mut indexed: Box<dyn Scheduler> = match variant {
            0 => Box::new(Vtc::new()),
            1 => Box::new(Vtc::with_predictions()),
            _ => Box::new(EquinoxSched::default_params(2000.0)),
        };
        let mut linear: Box<dyn Scheduler> = match variant {
            0 => Box::new(LinearVtc::new()),
            1 => Box::new(LinearVtc::with_predictions()),
            _ => Box::new(LinearEquinox::default_params(2000.0)),
        };
        let mut in_flight: Vec<Request> = Vec::new();
        for step in 0..400u64 {
            match rng.below(12) {
                0..=4 => {
                    let r = random_request(rng, step);
                    indexed.enqueue(r.clone(), step as f64);
                    linear.enqueue(r, step as f64);
                }
                5..=7 => {
                    // Deterministic pseudo-random feasibility shared by
                    // both sides: a request is infeasible iff its id
                    // hashes into the rejected residue this round.
                    let salt = rng.next_u64() | 1;
                    let admit_all = rng.chance(0.7);
                    let mut feas = |r: &Request| {
                        admit_all || r.id.0.wrapping_mul(salt).rotate_left(17) % 4 != 0
                    };
                    let a = indexed.pick(step as f64, &mut feas);
                    let b = linear.pick(step as f64, &mut feas);
                    assert_eq!(
                        a.as_ref().map(|r| r.id),
                        b.as_ref().map(|r| r.id),
                        "pick order diverged at step {step}"
                    );
                    if let Some(r) = a {
                        in_flight.push(r);
                    }
                }
                8 => {
                    if !in_flight.is_empty() {
                        let idx = rng.below(in_flight.len() as u64) as usize;
                        let r = in_flight.swap_remove(idx);
                        indexed.requeue(r.clone());
                        linear.requeue(r);
                    }
                }
                9..=10 => {
                    if !in_flight.is_empty() {
                        let idx = rng.below(in_flight.len() as u64) as usize;
                        let r = in_flight.swap_remove(idx);
                        let actual = Actuals {
                            latency: rng.f64() * 20.0,
                            gpu_util: rng.f64(),
                            tps: rng.range_f64(10.0, 4000.0),
                            output_tokens: rng.range(1, 512) as u32,
                        };
                        indexed.on_complete(&r, &actual, step as f64);
                        linear.on_complete(&r, &actual, step as f64);
                    }
                }
                _ => {
                    // Per-token service feedback for a random in-flight
                    // client (exercises baseline-VTC index refreshes).
                    if !in_flight.is_empty() {
                        let idx = rng.below(in_flight.len() as u64) as usize;
                        let c = in_flight[idx].client;
                        indexed.on_progress(c, 4.0);
                        linear.on_progress(c, 4.0);
                    }
                }
            }
            assert_eq!(indexed.queue_len(), linear.queue_len());
            assert_eq!(indexed.queued_clients(), linear.queued_clients());
        }
        // Final drain must agree pick-by-pick.
        loop {
            let a = indexed.pick(1e6, &mut |_| true);
            let b = linear.pick(1e6, &mut |_| true);
            assert_eq!(a.as_ref().map(|r| r.id), b.as_ref().map(|r| r.id), "drain diverged");
            if a.is_none() {
                break;
            }
        }
    });
}

/// Differential spec test on the ADVERSARIAL scenario set: the indexed
/// schedulers must match their linear-scan references pick-for-pick on
/// real hostile traces (heavy hitters, churn, flash crowds, tier
/// mixes...), not just on the random operation sequences above —
/// reactivation lifts, for instance, only fire on the churn-shaped
/// arrival patterns a uniform random stream almost never produces.
#[test]
fn prop_indexed_matches_linear_on_adversarial_traces() {
    for sc in equinox::workload::adversarial::registry() {
        for variant in 0..3u32 {
            let seed = 0x5eed ^ ((variant as u64) << 32);
            let trace = sc.trace(true, seed ^ 0x9e37_79b9);
            let mut indexed: Box<dyn Scheduler> = match variant {
                0 => Box::new(Vtc::new()),
                1 => Box::new(Vtc::with_predictions()),
                _ => Box::new(EquinoxSched::default_params(2000.0)),
            };
            let mut linear: Box<dyn Scheduler> = match variant {
                0 => Box::new(LinearVtc::new()),
                1 => Box::new(LinearVtc::with_predictions()),
                _ => Box::new(LinearEquinox::default_params(2000.0)),
            };
            let mut rng = Rng::new(seed);
            let mut in_flight: Vec<Request> = Vec::new();
            let label = format!("{}/{}", sc.name, indexed.name());
            // Replay the trace arrivals in order, interleaving picks,
            // requeues, completions and per-token progress between them.
            for (step, req) in trace.requests.iter().take(160).enumerate() {
                let mut r = req.clone();
                r.predicted_output_tokens = r.true_output_tokens;
                r.predicted_latency = 1.0;
                r.predicted_tps = 1000.0;
                r.predicted_gpu_util = 0.8;
                let now = r.arrival;
                indexed.enqueue(r.clone(), now);
                linear.enqueue(r, now);
                for _ in 0..rng.below(3) {
                    let salt = rng.next_u64() | 1;
                    let admit_all = rng.chance(0.7);
                    let mut feas = |x: &Request| {
                        admit_all || x.id.0.wrapping_mul(salt).rotate_left(17) % 4 != 0
                    };
                    let a = indexed.pick(now, &mut feas);
                    let b = linear.pick(now, &mut feas);
                    assert_eq!(
                        a.as_ref().map(|x| x.id),
                        b.as_ref().map(|x| x.id),
                        "{label}: pick diverged at arrival {step}"
                    );
                    if let Some(x) = a {
                        in_flight.push(x);
                    }
                }
                if !in_flight.is_empty() && rng.chance(0.15) {
                    let idx = rng.below(in_flight.len() as u64) as usize;
                    let x = in_flight.swap_remove(idx);
                    indexed.requeue(x.clone());
                    linear.requeue(x);
                }
                if !in_flight.is_empty() && rng.chance(0.5) {
                    let idx = rng.below(in_flight.len() as u64) as usize;
                    let x = in_flight.swap_remove(idx);
                    let actual = Actuals {
                        latency: rng.f64() * 10.0,
                        gpu_util: rng.f64(),
                        tps: rng.range_f64(100.0, 3000.0),
                        output_tokens: x.true_output_tokens,
                    };
                    indexed.on_complete(&x, &actual, now + 1.0);
                    linear.on_complete(&x, &actual, now + 1.0);
                }
                if !in_flight.is_empty() && rng.chance(0.6) {
                    let c = in_flight[rng.below(in_flight.len() as u64) as usize].client;
                    indexed.on_progress(c, 4.0);
                    linear.on_progress(c, 4.0);
                }
                assert_eq!(indexed.queue_len(), linear.queue_len(), "{label}");
                assert_eq!(indexed.queued_clients(), linear.queued_clients(), "{label}");
            }
            // Drain: final pick order must agree to the last request.
            loop {
                let a = indexed.pick(1e9, &mut |_| true);
                let b = linear.pick(1e9, &mut |_| true);
                assert_eq!(
                    a.as_ref().map(|x| x.id),
                    b.as_ref().map(|x| x.id),
                    "{label}: drain diverged"
                );
                if a.is_none() {
                    break;
                }
            }
            in_flight.clear();
        }
    }
}

/// Storage-family parity: the SAME generic scheduler code instantiated
/// over dense `ClientSlab` storage (production default) and `BTreeMap`
/// storage (reference) must agree pick-for-pick on random operation
/// sequences — the slab's ascending-id iteration is bit-compatible with
/// BTreeMap key order, so the storage family may never change a
/// decision. Complements `tests/scale.rs`, which checks the same
/// contract end-to-end (full-engine fingerprints on the adversarial
/// registry).
#[test]
fn prop_slab_storage_matches_btreemap_pick_order() {
    use equinox::sched::{HfParams, MapEquinox, MapVtc};
    check("slab == btreemap pick order", 24, |rng| {
        let variant = rng.below(3);
        let mut slab: Box<dyn Scheduler> = match variant {
            0 => Box::new(Vtc::new()),
            1 => Box::new(Vtc::with_predictions()),
            _ => Box::new(EquinoxSched::default_params(2000.0)),
        };
        let mut btree: Box<dyn Scheduler> = match variant {
            0 => Box::new(MapVtc::for_family()),
            1 => Box::new(MapVtc::for_family_with_predictions()),
            _ => Box::new(MapEquinox::for_family(HfParams::default(), 2000.0)),
        };
        let mut in_flight: Vec<Request> = Vec::new();
        for step in 0..300u64 {
            match rng.below(12) {
                0..=4 => {
                    let r = random_request(rng, step);
                    slab.enqueue(r.clone(), step as f64);
                    btree.enqueue(r, step as f64);
                }
                5..=7 => {
                    let salt = rng.next_u64() | 1;
                    let admit_all = rng.chance(0.7);
                    let mut feas = |r: &Request| {
                        admit_all || r.id.0.wrapping_mul(salt).rotate_left(17) % 4 != 0
                    };
                    let a = slab.pick(step as f64, &mut feas);
                    let b = btree.pick(step as f64, &mut feas);
                    assert_eq!(
                        a.as_ref().map(|r| r.id),
                        b.as_ref().map(|r| r.id),
                        "storage families diverged at step {step}"
                    );
                    if let Some(r) = a {
                        in_flight.push(r);
                    }
                }
                8 => {
                    if !in_flight.is_empty() {
                        let idx = rng.below(in_flight.len() as u64) as usize;
                        let r = in_flight.swap_remove(idx);
                        slab.requeue(r.clone());
                        btree.requeue(r);
                    }
                }
                9..=10 => {
                    if !in_flight.is_empty() {
                        let idx = rng.below(in_flight.len() as u64) as usize;
                        let r = in_flight.swap_remove(idx);
                        let actual = Actuals {
                            latency: rng.f64() * 20.0,
                            gpu_util: rng.f64(),
                            tps: rng.range_f64(10.0, 4000.0),
                            output_tokens: rng.range(1, 512) as u32,
                        };
                        slab.on_complete(&r, &actual, step as f64);
                        btree.on_complete(&r, &actual, step as f64);
                    }
                }
                _ => {
                    if !in_flight.is_empty() {
                        let idx = rng.below(in_flight.len() as u64) as usize;
                        let c = in_flight[idx].client;
                        slab.on_progress(c, 4.0);
                        btree.on_progress(c, 4.0);
                    }
                }
            }
            assert_eq!(slab.queue_len(), btree.queue_len());
            assert_eq!(slab.queued_clients(), btree.queued_clients());
        }
        loop {
            let a = slab.pick(1e6, &mut |_| true);
            let b = btree.pick(1e6, &mut |_| true);
            assert_eq!(a.as_ref().map(|r| r.id), b.as_ref().map(|r| r.id), "drain diverged");
            if a.is_none() {
                break;
            }
        }
    });
}

/// Guard no-op identity: under Oracle predictions the calibration guard
/// is a BITWISE no-op. Zero log-error keeps every EWMA at exactly 0.0
/// and the debias factor at exactly 1.0, so guarded admission charges
/// are bit-identical to unguarded ones — same picks, same fingerprints,
/// same flight-recorder event stream. Checked across the full
/// adversarial registry × {VTC+pred, Equinox} × {debias, ladder} on a
/// traced cluster cell (the trace digest folds every event, so even a
/// single perturbed decision or spurious GuardTransition breaks it).
#[test]
fn prop_oracle_guard_is_bitwise_noop() {
    use equinox::cluster::{run_cluster, ClusterOpts, Fleet, RouterKind};
    use equinox::obs::TraceCfg;
    use equinox::sched::GuardPolicy;

    let fleet = Fleet::homogeneous(2);
    for sc in equinox::workload::adversarial::registry() {
        let seed = 0x0ac1e ^ equinox::harness::derive_seed(42, sc.name, "oracle-guard-noop");
        let trace = sc.trace(true, seed);
        if trace.is_empty() {
            continue;
        }
        let run = |kind: SchedKind| {
            let opts = ClusterOpts::new(seed).with_trace(TraceCfg::default());
            run_cluster(
                fleet.clone(),
                RouterKind::FairShare.make(),
                kind,
                PredKind::Oracle,
                &trace,
                &opts,
            )
        };
        for (base, guarded) in [
            (SchedKind::VtcPred, |p| SchedKind::VtcPredGuarded(p)),
            (SchedKind::Equinox, |p| SchedKind::EquinoxGuarded(p)),
        ] as [(SchedKind, fn(GuardPolicy) -> SchedKind); 2]
        {
            let plain = run(base);
            let plain_trace = plain.trace.as_ref().expect("tracing enabled").digest();
            for policy in [GuardPolicy::Debias, GuardPolicy::Ladder] {
                let g = run(guarded(policy));
                let label = format!("{}/{}", sc.name, guarded(policy).label());
                assert_eq!(
                    plain.fingerprint(),
                    g.fingerprint(),
                    "{label}: guard perturbed an Oracle-fed run"
                );
                assert_eq!(
                    plain_trace,
                    g.trace.as_ref().expect("tracing enabled").digest(),
                    "{label}: guard perturbed the Oracle-fed event stream"
                );
                for h in g.guard_health.iter().flatten() {
                    assert_eq!(h.transitions, 0, "{label}: phantom guard transition");
                    assert_eq!(h.abs_err_ewma, 0.0, "{label}: nonzero error under Oracle");
                    assert_eq!(h.debias_factor, 1.0, "{label}: nonunit factor under Oracle");
                }
            }
        }
    }
}

/// HF monotonicity: a client that keeps receiving service must
/// (weakly) lose priority relative to an idle-but-backlogged peer.
#[test]
fn prop_hf_priority_decays_with_service() {
    check("hf priority decay", 64, |rng| {
        let mut s = EquinoxSched::default_params(2000.0);
        // Register both clients with queued work.
        s.enqueue(random_request(rng, 1_000_001), 0.0);
        let mut c1_req = random_request(rng, 1_000_002);
        c1_req.client = ClientId(5);
        s.enqueue(c1_req, 0.0);
        let hf1_before = s.hf(ClientId(5));
        // Serve client 0 a few times.
        for i in 0..rng.range(1, 6) {
            let mut r = random_request(rng, i);
            r.client = ClientId(0);
            s.enqueue(r, 0.0);
            // Admit specifically client 0's head by making others infeasible.
            let picked = s.pick(0.0, &mut |x: &Request| x.client == ClientId(0));
            if picked.is_none() {
                break;
            }
        }
        let (ufc0, _) = s.raw(ClientId(0));
        assert!(ufc0 > 0.0, "client 0 must have been charged");
        // Client 5 untouched → its HF must not exceed client 0's.
        assert!(
            s.hf(ClientId(5)) <= s.hf(ClientId(0)) + 1e-9,
            "served client must not out-prioritise idle one"
        );
        // And client 5's absolute HF must not have risen from service to 0.
        assert!(s.hf(ClientId(5)) <= hf1_before + 1e-9 + 0.3 * 1000.0);
    });
}
