//! Differential tests for the parallel cluster driver: the zero-drift
//! contract of this PR's tentpole.
//!
//! `DriveMode::Parallel{threads}` must produce bit-identical results —
//! `fingerprint()` word-for-word, `digest()` equal — to the serial
//! lock-step reference on every adversarial cluster scenario × router ×
//! fleet preset, at every thread count (including `threads: 1`, which
//! exercises the barrier/horizon logic without concurrency, and auto).
//! Parallelism may only change wall-clock time, never a simulated
//! outcome: the same contract PR 2 pinned for macro≡micro stepping and
//! PR 4 for 1-replica-cluster≡plain-engine.

use equinox::cluster::{run_cluster, ClusterOpts, ClusterResult, DriveMode, Fleet, RouterKind};
use equinox::exp::{run_sim, PredKind, SchedKind};
use equinox::harness::cluster::{cluster_trace, ROUTERS, SCENARIOS};
use equinox::harness::{self, derive_seed};
use equinox::sim::SimConfig;
use equinox::workload::Trace;

fn run_with(
    trace: &Trace,
    fleet: &Fleet,
    router: RouterKind,
    seed: u64,
    drive: DriveMode,
) -> ClusterResult {
    let opts = ClusterOpts::new(seed).with_drive(drive);
    run_cluster(fleet.clone(), router.make(), SchedKind::Equinox, PredKind::Mope, trace, &opts)
}

/// The acceptance bar: serial ≡ parallel fingerprints over the full
/// cluster matrix (scenarios × routers × fleet presets) at threads ∈
/// {1, 2, 8}.
#[test]
fn parallel_is_bit_exact_vs_serial_across_the_matrix() {
    for scenario in SCENARIOS {
        for fleet in [Fleet::homogeneous(4), Fleet::hetero()] {
            for router in ROUTERS {
                let label = format!("par/{}@{}", router.label(), fleet.name);
                let seed = derive_seed(42, scenario, &label);
                let trace = cluster_trace(scenario, fleet.len(), true, seed);
                let serial = run_with(&trace, &fleet, router, seed, DriveMode::Serial);
                assert_eq!(
                    serial.finished(),
                    serial.total_requests(),
                    "{scenario}/{}/{}: serial reference must drain",
                    fleet.name,
                    router.label()
                );
                let reference = serial.fingerprint();
                for threads in [1usize, 2, 8] {
                    let par =
                        run_with(&trace, &fleet, router, seed, DriveMode::Parallel { threads });
                    assert_eq!(
                        par.fingerprint(),
                        reference,
                        "{scenario}/{}/{} threads={threads}: parallel diverged from serial",
                        fleet.name,
                        router.label()
                    );
                    assert_eq!(par.digest(), serial.digest());
                }
            }
        }
    }
}

/// Running the identical parallel config twice must be bit-identical —
/// thread scheduling can never leak into results (all reductions happen
/// on the driver thread in replica-id order).
#[test]
fn parallel_replay_is_bit_identical() {
    let seed = derive_seed(42, "heavy_hitter", "par-replay");
    let fleet = Fleet::hetero();
    let trace = cluster_trace("heavy_hitter", fleet.len(), true, seed);
    let drive = DriveMode::Parallel { threads: 8 };
    let a = run_with(&trace, &fleet, RouterKind::FairShare, seed, drive);
    let b = run_with(&trace, &fleet, RouterKind::FairShare, seed, drive);
    assert_eq!(a.fingerprint(), b.fingerprint());
    assert_eq!(a.digest(), b.digest());
}

/// Thread count is a pure execution knob: 1, 2, 8 and auto (0) all
/// produce the same digest.
#[test]
fn thread_count_never_affects_results() {
    let seed = derive_seed(42, "flash_crowd", "par-threads");
    let fleet = Fleet::homogeneous(4);
    let trace = cluster_trace("flash_crowd", fleet.len(), true, seed);
    let digests: Vec<u64> = [0usize, 1, 2, 8]
        .iter()
        .map(|&threads| {
            run_with(&trace, &fleet, RouterKind::JoinShortestQueue, seed, DriveMode::Parallel {
                threads,
            })
            .digest()
        })
        .collect();
    assert!(
        digests.windows(2).all(|w| w[0] == w[1]),
        "digests diverged across thread counts: {digests:?}"
    );
}

/// The barrier logic must agree with the serial reference at every sync
/// density: sub-second boundaries (many barriers per routing gate),
/// sparse boundaries (many gates per barrier), and syncing disabled.
#[test]
fn parallel_matches_serial_across_sync_periods() {
    let seed = derive_seed(42, "tenant_churn", "par-sync");
    let fleet = Fleet::hetero();
    let trace = cluster_trace("tenant_churn", fleet.len(), true, seed);
    for sync_period in [0.0, 0.25, 5.0] {
        let run = |drive: DriveMode| {
            let opts = ClusterOpts {
                sync_period,
                drive,
                ..ClusterOpts::new(seed)
            };
            run_cluster(
                fleet.clone(),
                RouterKind::FairShare.make(),
                SchedKind::Equinox,
                PredKind::Mope,
                &trace,
                &opts,
            )
        };
        let serial = run(DriveMode::Serial);
        let par = run(DriveMode::Parallel { threads: 3 });
        assert_eq!(
            par.fingerprint(),
            serial.fingerprint(),
            "sync_period={sync_period}: parallel diverged from serial"
        );
    }
}

/// Transitivity anchor: a parallel solo cluster is still bit-identical
/// to the plain single engine (serial≡parallel composed with PR 4's
/// solo-cluster≡engine), checked directly for belt and braces.
#[test]
fn parallel_solo_cluster_matches_plain_engine() {
    let seed = derive_seed(42, "heavy_hitter", "par-solo");
    let sc = equinox::workload::adversarial::find("heavy_hitter").unwrap();
    let trace = sc.trace(true, seed);
    let plain = run_sim(&SimConfig::a100_7b_vllm(), SchedKind::Equinox, PredKind::Mope, &trace, seed);
    let opts = ClusterOpts::new(seed).with_drive(DriveMode::Parallel { threads: 4 });
    let cluster = run_cluster(
        Fleet::solo(),
        RouterKind::RoundRobin.make(),
        SchedKind::Equinox,
        PredKind::Mope,
        &trace,
        &opts,
    );
    assert_eq!(cluster.replicas.len(), 1);
    assert_eq!(
        harness::fingerprint(&cluster.replicas[0]),
        harness::fingerprint(&plain),
        "parallel solo cluster drifted from the plain engine"
    );
}
