//! Integration tests over the REAL artifacts (requires `make artifacts`).
//! Skipped gracefully when artifacts are absent so `cargo test` works in
//! a fresh checkout; CI runs `make test`, which builds them first.

use equinox::core::ClientId;
use equinox::runtime::engine::{EngineConfig, ServeEngine};
use equinox::runtime::mope_rt::MopePredictor;
use equinox::runtime::pjrt::Runtime;
use equinox::runtime::{features, tokenizer, Manifest};
use std::path::PathBuf;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn manifest_loads_and_describes_model() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    assert_eq!(m.model.name, "tinylm");
    assert!(m.prefill_for(10).is_some());
    assert!(m.decode_for(1).is_some());
    assert!(m.mope.is_some());
}

#[test]
fn engine_generates_deterministically() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let mut engine = ServeEngine::new(&rt, &EngineConfig::new(&dir)).unwrap();
    let prompt = tokenizer::encode("what is rust?");
    let out1 = engine.generate(&prompt, 8).unwrap();
    assert_eq!(out1.len(), 8);
    // Greedy decoding of the same prompt must reproduce exactly.
    let out2 = engine.generate(&prompt, 8).unwrap();
    assert_eq!(out1, out2);
    // All tokens in vocabulary.
    for &t in &out1 {
        assert!((0..512).contains(&t));
    }
}

#[test]
fn engine_batches_isolated_sequences() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let mut engine = ServeEngine::new(&rt, &EngineConfig::new(&dir)).unwrap();

    // Solo generation for reference.
    let p1 = tokenizer::encode("explain tcp congestion control in detail");
    let p2 = tokenizer::encode("list 10 facts about tokyo");
    let solo1 = engine.generate(&p1, 6).unwrap();
    let solo2 = engine.generate(&p2, 6).unwrap();

    // Same prompts concurrently in one batch.
    let (s1, f1) = engine.add_request(&p1, 6).unwrap();
    let (s2, f2) = engine.add_request(&p2, 6).unwrap();
    assert_eq!(f1, solo1[0]);
    assert_eq!(f2, solo2[0]);
    let mut got1 = vec![f1];
    let mut got2 = vec![f2];
    for _ in 0..6 {
        for ev in engine.step().unwrap() {
            if ev.slot == s1 {
                got1.push(ev.token);
            } else if ev.slot == s2 {
                got2.push(ev.token);
            }
        }
    }
    assert_eq!(got1, solo1, "batching must not change sequence 1");
    assert_eq!(got2, solo2, "batching must not change sequence 2");
}

#[test]
fn mope_expert_predicts_by_prompt_class() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let manifest = Manifest::load(&dir).unwrap();
    let mope = MopePredictor::load(&rt, &manifest).unwrap();

    let short = features::extract("define sourdough in one sentence.", 8);
    let long = features::extract("write an essay comparing rust lifetimes and its alternatives.", 20);
    let preds = mope.predict(&[short, long]).unwrap();
    assert!(preds[0] >= 1 && preds[0] <= 1024);
    assert!(preds[1] >= 1 && preds[1] <= 1024);
    assert!(
        preds[1] > 2 * preds[0],
        "essay prompt must predict much longer than define: {preds:?}"
    );
}

#[test]
fn engine_rejects_oversized_prompts() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let mut engine = ServeEngine::new(&rt, &EngineConfig::new(&dir)).unwrap();
    assert!(!engine.can_admit(10_000, 8));
    let long: Vec<i32> = (0..10_000).map(|i| (i % 500) as i32).collect();
    assert!(engine.add_request(&long, 8).is_err());
}

#[test]
fn service_end_to_end_multi_client() {
    let Some(dir) = artifacts_dir() else { return };
    use equinox::server::service::{ServeService, ServiceConfig};
    let service = ServeService::start(ServiceConfig::new(&dir)).unwrap();
    let mut handles = Vec::new();
    let service = std::sync::Arc::new(service);
    for c in 0..3u32 {
        let s = service.clone();
        handles.push(std::thread::spawn(move || {
            s.generate(ClientId(c), "what is rust?", 4).unwrap()
        }));
    }
    for h in handles {
        let done = h.join().unwrap();
        assert_eq!(done.output_tokens, 4);
        assert!(done.ttft > 0.0 && done.e2e >= done.ttft);
    }
    assert_eq!(
        service.stats.completed.load(std::sync::atomic::Ordering::Relaxed),
        3
    );
}
