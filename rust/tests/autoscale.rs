//! Autoscale tier: the deterministic-elasticity contracts of this PR's
//! tentpole.
//!
//! 1. **Zero drift** — `DriveMode::Parallel` is fingerprint-identical to
//!    `DriveMode::Serial` under every scale policy (scheduled and
//!    reactive), at threads ∈ {2, 8}, and with a fault plan layered on
//!    top: scale transitions materialize only at barrier boundaries, so
//!    elasticity may never change a simulated outcome.
//! 2. **Conservation across drains** — scale-in retires replicas through
//!    the orphan-migration path; per-client delivered service equals
//!    offered demand exactly even when the drained replica had queued
//!    and running work.
//! 3. **Epoch ledger** — `fleet_epochs` records every composition change
//!    and is folded into the fingerprint (replay bit-exactness covers
//!    it).
//! 4. **Metric tripwire** — the rewritten single-pass co-backlogged
//!    discrepancy metric stays fast at 10k tenants (the old all-pairs
//!    form was O(C²·T) and would blow straight past the budget).
//! 5. **Acceptance bar** — reactive scale-out on a flash crowd strictly
//!    beats the static minimal fleet on post-spike co-backlogged
//!    discrepancy, machine-checked.

use equinox::cluster::{
    run_cluster, AutoscalePolicy, ClusterOpts, ClusterResult, DriveMode, FaultPlan, Fleet,
    ReplicaSpec, RouterKind, ScaleEvent,
};
use equinox::core::ClientId;
use equinox::exp::{PredKind, SchedKind};
use equinox::harness::autoscale::{autoscale_horizon, autoscale_policy};
use equinox::harness::cluster::cluster_trace;
use equinox::harness::derive_seed;
use equinox::workload::Trace;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

fn run_with(
    trace: &Trace,
    fleet: &Fleet,
    policy: AutoscalePolicy,
    plan: FaultPlan,
    seed: u64,
    drive: DriveMode,
) -> ClusterResult {
    let opts =
        ClusterOpts::new(seed).with_drive(drive).with_autoscale(policy).with_faults(plan);
    run_cluster(
        fleet.clone(),
        RouterKind::FairShare.make(),
        SchedKind::Equinox,
        PredKind::Mope,
        trace,
        &opts,
    )
}

/// The zero-drift acceptance bar: serial ≡ parallel fingerprints under
/// both policy shapes on both stress scenarios, at threads ∈ {2, 8}.
#[test]
fn parallel_is_bit_exact_vs_serial_under_every_policy() {
    let fleet = Fleet::minimal();
    for scenario in ["flash_crowd", "heavy_hitter"] {
        let horizon = autoscale_horizon(scenario, true);
        for policy_name in ["scheduled", "reactive"] {
            let policy = autoscale_policy(policy_name, horizon).unwrap();
            let label = format!("autoscale-par/{policy_name}");
            let seed = derive_seed(42, scenario, &label);
            let trace = cluster_trace(scenario, fleet.len(), true, seed);
            let serial =
                run_with(&trace, &fleet, policy.clone(), FaultPlan::none(), seed, DriveMode::Serial);
            assert_eq!(
                serial.finished(),
                serial.total_requests(),
                "{scenario}/{policy_name}: serial reference must drain"
            );
            let reference = serial.fingerprint();
            for threads in [2usize, 8] {
                let par = run_with(
                    &trace,
                    &fleet,
                    policy.clone(),
                    FaultPlan::none(),
                    seed,
                    DriveMode::Parallel { threads },
                );
                assert_eq!(
                    par.fingerprint(),
                    reference,
                    "{scenario}/{policy_name} threads={threads}: parallel diverged from serial"
                );
                assert_eq!(par.digest(), serial.digest());
            }
        }
    }
}

/// Scale and fault barriers compose: a crash-recover plan layered under
/// each policy still drives serial ≡ parallel bit-exactly (the barrier
/// check order faults → scale → sync is fixed in both modes).
#[test]
fn scale_and_fault_barriers_compose_bit_exactly() {
    let fleet = Fleet::minimal();
    let scenario = "flash_crowd";
    let horizon = autoscale_horizon(scenario, true);
    let plan = FaultPlan::crash_recover(0, 0.25 * horizon, 0.6 * horizon);
    for policy_name in ["scheduled", "reactive"] {
        let policy = autoscale_policy(policy_name, horizon).unwrap();
        let seed = derive_seed(42, scenario, &format!("autoscale-faulted/{policy_name}"));
        let trace = cluster_trace(scenario, fleet.len(), true, seed);
        let serial =
            run_with(&trace, &fleet, policy.clone(), plan.clone(), seed, DriveMode::Serial);
        let par = run_with(
            &trace,
            &fleet,
            policy.clone(),
            plan.clone(),
            seed,
            DriveMode::Parallel { threads: 2 },
        );
        assert_eq!(
            par.fingerprint(),
            serial.fingerprint(),
            "{policy_name}: faulted autoscale run diverged across drives"
        );
        assert!(serial.fault_transitions > 0, "{policy_name}: fault plan never materialized");
    }
}

/// Replaying the identical config is bit-identical — reactive decisions
/// are a pure function of barrier-time state, and the fingerprint folds
/// in the full epoch ledger.
#[test]
fn autoscaled_replay_is_bit_identical() {
    let fleet = Fleet::minimal();
    let horizon = autoscale_horizon("flash_crowd", true);
    let policy = autoscale_policy("reactive", horizon).unwrap();
    let seed = derive_seed(42, "flash_crowd", "autoscale-replay");
    let trace = cluster_trace("flash_crowd", fleet.len(), true, seed);
    let drive = DriveMode::Parallel { threads: 8 };
    let a = run_with(&trace, &fleet, policy.clone(), FaultPlan::none(), seed, drive);
    let b = run_with(&trace, &fleet, policy, FaultPlan::none(), seed, drive);
    assert_eq!(a.fingerprint(), b.fingerprint());
    assert_eq!(a.digest(), b.digest());
}

/// Conservation across a mid-overload drain: the victim replica is
/// retired while it still holds queued/running work, its orphans migrate
/// through the router, and per-client delivered service still equals
/// offered demand exactly (rework is excluded by the watermark carry).
#[test]
fn scale_in_drains_conserve_service_exactly() {
    let fleet = Fleet::minimal();
    let horizon = autoscale_horizon("heavy_hitter", true);
    // Grow an A100-80GB into sustained overload, then retire it at the
    // midpoint — while queues are still deep, so the drain must move
    // real work.
    let policy = AutoscalePolicy::Schedule(vec![
        ScaleEvent::grow(0.3 * horizon, ReplicaSpec::a100_80g()),
        ScaleEvent::shrink(0.5 * horizon),
    ]);
    let seed = derive_seed(42, "heavy_hitter", "autoscale-drain");
    let trace = cluster_trace("heavy_hitter", fleet.len(), true, seed);
    let res = run_with(&trace, &fleet, policy, FaultPlan::none(), seed, DriveMode::Serial);

    assert_eq!(res.scale_transitions, 2, "grow and shrink must both apply");
    assert_eq!(res.fleet_epochs.len(), 3, "construction + grow + drain epochs");
    assert_eq!(res.fleet_epochs[1].1.len(), 3);
    assert_eq!(res.fleet_epochs[2].1.len(), 2, "retired replica leaves the composition");
    let migrated: u64 = res.migrated.iter().sum();
    assert!(migrated > 0, "mid-overload drain must migrate orphans");

    assert_eq!(res.finished(), trace.len(), "every request survives the drain");
    assert_eq!(res.shed_count(), 0);
    let mut demand: BTreeMap<ClientId, f64> = BTreeMap::new();
    for r in trace.requests.iter() {
        *demand.entry(r.client).or_insert(0.0) += r.weighted_tokens();
    }
    for (&c, &d) in &demand {
        let s = res.service_total(c);
        assert!(
            (s - d).abs() <= 1e-6 * d.max(1.0),
            "service conservation broke across the drain: client {c} served {s} of {d}"
        );
    }
}

/// The rewritten single-pass discrepancy metric stays fast at 10k
/// tenants. The old all-pairs form was O(C²·T): at C = 10_000 it
/// enumerates ~5·10⁷ pairs per timeline sample and would blow straight
/// past this budget; the single-pass rewrite is O(Σ|set|·log C).
#[test]
fn linear_discrepancy_metric_survives_10k_tenants() {
    use equinox::workload::{generate, Scenario};
    let sc = Scenario::heavy_hitter(9, 4.0).with_clients(10_000);
    let trace = generate(&sc, 7);
    assert!(trace.num_clients() > 5_000, "population failed to materialise");
    let fleet = Fleet::minimal();
    let opts = ClusterOpts::new(7);
    let res = run_cluster(
        fleet,
        RouterKind::RoundRobin.make(),
        SchedKind::Equinox,
        PredKind::Mope,
        &trace,
        &opts,
    );
    let t = Instant::now();
    let disc = res.max_co_backlogged_diff();
    let post = res.max_co_backlogged_diff_after(2.0);
    assert!(
        t.elapsed() < Duration::from_secs(30),
        "10k-tenant discrepancy metric too slow: {:?}",
        t.elapsed()
    );
    assert!(disc.is_finite() && disc >= 0.0);
    assert!(post.is_finite() && post <= disc + 1e-9);
}

/// The headline elasticity claim, machine-checked: on a flash crowd over
/// the minimal fleet, the reactive controller scales out under the spike
/// and strictly beats the static fleet on post-spike co-backlogged
/// discrepancy — the static arm is still digesting its backlog long
/// after the burst, the scaled arm has already re-converged.
#[test]
fn reactive_scaling_beats_static_on_post_spike_discrepancy() {
    let fleet = Fleet::minimal();
    let horizon = autoscale_horizon("flash_crowd", true);
    let post_spike = 0.75 * horizon;
    let seed = derive_seed(42, "flash_crowd", "autoscale-accept");
    let trace = cluster_trace("flash_crowd", fleet.len(), true, seed);

    let stat =
        run_with(&trace, &fleet, AutoscalePolicy::Off, FaultPlan::none(), seed, DriveMode::Serial);
    let policy = autoscale_policy("reactive", horizon).unwrap();
    let reactive = run_with(&trace, &fleet, policy, FaultPlan::none(), seed, DriveMode::Serial);

    assert_eq!(stat.scale_transitions, 0);
    assert!(
        reactive.scale_transitions > 0,
        "the flash crowd must trip the backlog controller on the minimal fleet"
    );
    assert_eq!(reactive.finished(), trace.len(), "scaled run must still drain everything");

    let stat_disc = stat.max_co_backlogged_diff_after(post_spike);
    let reactive_disc = reactive.max_co_backlogged_diff_after(post_spike);
    assert!(
        reactive_disc < stat_disc,
        "reactive scale-out must strictly beat the static fleet post-spike: \
         reactive {reactive_disc:.0} vs static {stat_disc:.0}"
    );
}
