//! Tier-1 fault-plane conformance: what the cluster must guarantee
//! while replicas crash, slow down, and lose KV — and what the harness
//! must catch when failover is (deliberately) broken.
//!
//! 1. **Chaos matrix** — scenario × fault-plan cells pass conservation
//!    modulo shed, survivor no-starvation, bounded post-recovery
//!    discrepancy, bit-exact replay AND serial ≡ parallel digests.
//! 2. **Migration wins** — on crash-recover × heavy_hitter × hetero,
//!    migrating orphans yields strictly lower post-recovery
//!    co-backlogged discrepancy than freezing them (`Wait`): the
//!    acceptance bar for the fault plane.
//! 3. **Negative control** — the lossy-failover fixture (orphans
//!    dropped, not booked as shed) must FAIL conservation.
//! 4. **CLI hardening** — garbage flag values and impossible options
//!    exit 2 with a diagnostic, never a silent default.

use equinox::cluster::{
    run_cluster, ClusterOpts, DriveMode, FaultPlan, Fleet, MigrationPolicy, RouterKind,
};
use equinox::exp::{PredKind, SchedKind};
use equinox::harness::broken::run_lossy_failover_fixture;
use equinox::harness::chaos::{
    chaos_horizon, check_chaos_run, run_chaos_matrix, CHAOS_PLANS, CHAOS_SCENARIOS,
};
use equinox::harness::cluster::cluster_trace;
use equinox::harness::{derive_seed, ConformanceOpts};
use equinox::sched::GuardPolicy;

#[test]
fn chaos_matrix_passes_with_bit_exact_drives() {
    let opts = ConformanceOpts::default();
    let cells = run_chaos_matrix(&opts);
    assert_eq!(cells.len(), CHAOS_SCENARIOS.len() * CHAOS_PLANS.len());
    for c in &cells {
        assert!(c.passed(), "{}: violations {:?} (notes {:?})", c.key(), c.violations, c.notes);
        // Conservation modulo shed: every request finished or was
        // accounted for at the admission gate.
        assert_eq!(c.finished + c.shed as usize, c.total, "{}: lost requests", c.key());
        if c.plan == "none" {
            assert_eq!(c.fault_transitions, 0, "{}: phantom fault", c.key());
        } else {
            assert!(c.fault_transitions > 0, "{}: plan never materialized", c.key());
        }
        if c.plan == "crash_recover" {
            assert!(c.migrated > 0, "{}: crash with queued work must migrate", c.key());
        }
    }
}

/// Acceptance bar: migrating a downed replica's orphans to survivors
/// strictly reduces the post-recovery co-backlogged discrepancy versus
/// letting them wait out the outage. Same trace, same crash, same
/// router (FairShare) — only the failover policy differs.
#[test]
fn migration_beats_wait_on_post_recovery_discrepancy() {
    let fleet = Fleet::hetero();
    let seed = derive_seed(42, "heavy_hitter", "migrate-vs-wait");
    let trace = cluster_trace("heavy_hitter", fleet.len(), true, seed);
    let h = chaos_horizon("heavy_hitter", true);
    // Replica 0 is the A100-80GB — losing the strongest replica puts
    // the most orphaned work at stake.
    let plan = FaultPlan::crash_recover(0, 0.25 * h, 0.6 * h);

    let run = |migration: MigrationPolicy| {
        let opts =
            ClusterOpts::new(seed).with_faults(plan.clone()).with_migration(migration);
        run_cluster(
            fleet.clone(),
            RouterKind::FairShare.make(),
            SchedKind::Equinox,
            PredKind::Mope,
            &trace,
            &opts,
        )
    };
    let migrate = run(MigrationPolicy::Migrate);
    let wait = run(MigrationPolicy::Wait);

    // Both policies eventually drain — Wait just drains later.
    assert_eq!(migrate.finished(), trace.len(), "migrate must drain");
    assert_eq!(wait.finished(), trace.len(), "wait must drain after recovery");
    assert!(migrate.migrated.iter().sum::<u64>() > 0, "crash must orphan queued work");
    assert_eq!(wait.migrated.iter().sum::<u64>(), 0, "wait must not migrate");

    let t0 = plan.last_recovery_at();
    let m = migrate.max_co_backlogged_diff_after(t0);
    let w = wait.max_co_backlogged_diff_after(t0);
    assert!(w > 0.0, "an outage this size must leave a post-recovery gap under Wait");
    assert!(
        m < w,
        "migration post-recovery discrepancy {m:.0} must be strictly below wait {w:.0}"
    );
}

/// Migration × prediction-mode audit: a request admitted under
/// predicted-token (guarded, state-dependent) charging and then
/// crash-migrated must have its admit receipt refunded exactly on the
/// source replica and re-charged on the destination without
/// double-counting. Observable consequences pinned here: every
/// replica's receipt map drains to zero (a receipt refunded never or
/// twice would linger or go negative-through-conservation), and the
/// full chaos invariant suite — including per-client service
/// conservation — holds with the guard attached.
#[test]
fn crash_migration_settles_guarded_admit_receipts_exactly() {
    let fleet = Fleet::hetero();
    let seed = derive_seed(42, "heavy_hitter", "guarded-migration-receipts");
    let trace = cluster_trace("heavy_hitter", fleet.len(), true, seed);
    let h = chaos_horizon("heavy_hitter", true);
    let plan = FaultPlan::crash_recover(0, 0.25 * h, 0.6 * h);

    for sched in [
        SchedKind::EquinoxGuarded(GuardPolicy::Debias),
        SchedKind::EquinoxGuarded(GuardPolicy::Ladder),
        SchedKind::Equinox,
    ] {
        let opts = ClusterOpts::new(seed)
            .with_faults(plan.clone())
            .with_migration(MigrationPolicy::Migrate);
        let res = run_cluster(
            fleet.clone(),
            RouterKind::FairShare.make(),
            sched,
            PredKind::Mope,
            &trace,
            &opts,
        );
        assert!(
            res.migrated.iter().sum::<u64>() > 0,
            "{sched:?}: crash with queued work must migrate"
        );
        for (i, r) in res.outstanding_receipts.iter().enumerate() {
            assert_eq!(
                *r,
                Some(0),
                "{sched:?}: replica {i} left admit receipts unsettled after crash migration"
            );
        }
        let (violations, _, _) = check_chaos_run(&trace, &res, &plan);
        assert!(violations.is_empty(), "{sched:?}: {violations:?}");
    }
}

/// Negative control: dropping orphans instead of migrating them (and
/// not booking them as shed) must be flagged by conservation-modulo-
/// shed. A harness that passes a lossy failover is vacuous.
#[test]
fn lossy_failover_fixture_fails_conservation() {
    let cell = run_lossy_failover_fixture(&ConformanceOpts::default());
    assert!(!cell.passed(), "the lossy fixture must fail the chaos harness");
    assert!(cell.finished < cell.total, "Drop must actually lose requests");
    assert_eq!(cell.shed, 0, "dropped orphans are not shed — that's the point");
    assert!(
        cell.violations.iter().any(|v| v.contains("conservation")),
        "expected a conservation violation, got {:?}",
        cell.violations
    );
}

/// Serial and parallel digests agree for a seeded multi-event plan at
/// several thread counts (the matrix checks 2 threads; this pins more).
#[test]
fn seeded_fault_plan_is_drive_invariant_across_thread_counts() {
    let fleet = Fleet::hetero();
    let seed = derive_seed(42, "flash_crowd", "seeded-drive-invariance");
    let trace = cluster_trace("flash_crowd", fleet.len(), true, seed);
    let plan = FaultPlan::seeded(seed, fleet.len(), chaos_horizon("flash_crowd", true));
    let run = |drive: DriveMode| {
        let opts = ClusterOpts::new(seed).with_faults(plan.clone()).with_drive(drive);
        run_cluster(
            fleet.clone(),
            RouterKind::FairShare.make(),
            SchedKind::Equinox,
            PredKind::Mope,
            &trace,
            &opts,
        )
    };
    let serial = run(DriveMode::Serial);
    for threads in [2usize, 3, 8] {
        let par = run(DriveMode::Parallel { threads });
        assert_eq!(
            serial.fingerprint(),
            par.fingerprint(),
            "parallel({threads}) drifted from serial under seeded faults"
        );
    }
}

// ---------------------------------------------------------------------
// CLI hardening: bad input exits 2 with a diagnostic on stderr.
// ---------------------------------------------------------------------

fn run_cli(args: &[&str]) -> std::process::Output {
    std::process::Command::new(env!("CARGO_BIN_EXE_equinox"))
        .args(args)
        .output()
        .expect("failed to spawn equinox binary")
}

#[test]
fn cli_rejects_unknown_enum_flags_listing_options() {
    for (args, expect) in [
        (vec!["cluster", "--router", "nope"], "round_robin|jsq|predicted_cost|fair_share"),
        (vec!["cluster", "--fleet", "nope"], "solo|homo4|hetero|skewed3"),
        (vec!["cluster", "--drive", "nope"], "serial|parallel"),
        (vec!["cluster", "--scenario", "nope"], "heavy_hitter|flash_crowd"),
        (vec!["chaos", "--drive", "nope"], "serial|parallel"),
    ] {
        let out = run_cli(&args);
        assert_eq!(out.status.code(), Some(2), "{args:?} must exit 2");
        let err = String::from_utf8_lossy(&out.stderr);
        assert!(err.contains(expect), "{args:?}: stderr {err:?} must list valid options");
    }
}

#[test]
fn cli_rejects_unparseable_flag_values() {
    for args in [
        vec!["cluster", "--sync", "bogus", "--quick"],
        vec!["cluster", "--seed", "not-a-number"],
        vec!["cluster", "--threads", "many"],
        vec!["chaos", "--seed", "nan?"],
    ] {
        let out = run_cli(&args);
        assert_eq!(out.status.code(), Some(2), "{args:?} must exit 2, not run with a default");
        let err = String::from_utf8_lossy(&out.stderr);
        assert!(err.contains("invalid value"), "{args:?}: stderr {err:?}");
    }
}

#[test]
fn cli_rejects_impossible_cluster_options() {
    let out = run_cli(&["cluster", "--sync", "-1", "--quick"]);
    assert_eq!(out.status.code(), Some(2), "negative sync must exit 2");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("sync period"), "stderr {err:?} must name the offending option");
}
