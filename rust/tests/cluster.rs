//! Tier-1 cluster conformance: the multi-replica subsystem's load-bearing
//! contracts.
//!
//! 1. **Zero drift** — a 1-replica cluster (any router) is bit-identical
//!    (fingerprint-equal) to the plain single-engine `Simulation` on
//!    EVERY adversarial scenario: the cluster layer is pure composition.
//! 2. **Cluster invariants** — the router × fleet × scenario matrix
//!    passes global service conservation, bounded cross-replica
//!    discrepancy (hard for FairShare), and deterministic replay.
//! 3. **Fairness-aware routing wins** — FairShare shows strictly lower
//!    cluster-wide max co-backlogged discrepancy than RoundRobin on
//!    heavy_hitter over the heterogeneous fleet (the acceptance bar).

use equinox::cluster::{run_cluster, ClusterOpts, ClusterResult, Fleet, RouterKind};
use equinox::exp::{run_sim, PredKind, SchedKind};
use equinox::harness::cluster::{run_cluster_matrix, ROUTERS, SCENARIOS};
use equinox::harness::{self, derive_seed, ConformanceOpts};
use equinox::sim::SimConfig;
use equinox::workload::adversarial;

fn pred_for(kind: SchedKind) -> PredKind {
    if kind == SchedKind::Equinox {
        PredKind::Mope
    } else {
        PredKind::Oracle
    }
}

fn solo_cluster(
    scenario: &str,
    sched: SchedKind,
    router: RouterKind,
    seed: u64,
) -> (ClusterResult, equinox::sim::SimResult) {
    let sc = adversarial::find(scenario).unwrap();
    let trace = sc.trace(true, seed);
    let plain = run_sim(&SimConfig::a100_7b_vllm(), sched, pred_for(sched), &trace, seed);
    let opts = ClusterOpts::new(seed);
    let cluster =
        run_cluster(Fleet::solo(), router.make(), sched, pred_for(sched), &trace, &opts);
    (cluster, plain)
}

/// Acceptance bar: 1-replica cluster ≡ plain engine, bit for bit, on
/// every adversarial scenario (Equinox local scheduler).
#[test]
fn solo_cluster_is_bit_identical_to_plain_engine_on_all_scenarios() {
    for sc in adversarial::registry() {
        let seed = derive_seed(42, sc.name, "solo-differential");
        let (cluster, plain) = solo_cluster(sc.name, SchedKind::Equinox, RouterKind::RoundRobin, seed);
        assert_eq!(cluster.replicas.len(), 1);
        assert_eq!(
            harness::fingerprint(&cluster.replicas[0]),
            harness::fingerprint(&plain),
            "{}: solo cluster drifted from the plain engine",
            sc.name
        );
    }
}

/// The zero-drift contract holds for every router (routing a 1-replica
/// fleet is trivial, but each policy still executes its full decision
/// path) and for a prediction-blind scheduler too.
#[test]
fn solo_cluster_zero_drift_across_routers_and_schedulers() {
    for router in [
        RouterKind::RoundRobin,
        RouterKind::JoinShortestQueue,
        RouterKind::PredictedCost,
        RouterKind::FairShare,
    ] {
        let (cluster, plain) = solo_cluster("heavy_hitter", SchedKind::Equinox, router, 1234);
        assert_eq!(
            harness::fingerprint(&cluster.replicas[0]),
            harness::fingerprint(&plain),
            "router {} drifted",
            router.label()
        );
    }
    for sched in [SchedKind::Vtc, SchedKind::Fcfs] {
        let (cluster, plain) = solo_cluster("flash_crowd", sched, RouterKind::FairShare, 99);
        assert_eq!(
            harness::fingerprint(&cluster.replicas[0]),
            harness::fingerprint(&plain),
            "scheduler {:?} drifted",
            sched
        );
    }
}

/// The issue's conformance matrix: {RoundRobin, JSQ, FairShare} ×
/// {homo 4×40GB, hetero 80+2×40} × {heavy_hitter, flash_crowd,
/// tenant_churn} — global conservation, bounded cross-replica
/// discrepancy, deterministic replay, all machine-checked per cell.
#[test]
fn cluster_conformance_matrix_passes() {
    let opts = ConformanceOpts::default();
    let cells = run_cluster_matrix(&opts);
    assert_eq!(cells.len(), SCENARIOS.len() * 2 * ROUTERS.len());
    for c in &cells {
        assert!(
            c.passed(),
            "{}: violations {:?} (notes {:?})",
            c.key(),
            c.violations,
            c.notes
        );
        assert_eq!(c.finished, c.total, "{}: must drain", c.key());
        assert!(c.digest != 0);
        let routed: u64 = c.routed.iter().sum();
        assert_eq!(routed as usize, c.total, "{}: routing lost requests", c.key());
        // Count-blind RoundRobin must use every replica on these
        // hundreds-of-requests traces (FairShare may legitimately
        // concentrate work for locality).
        if c.router == "round_robin" {
            assert!(
                c.routed.iter().all(|&n| n > 0),
                "{}: RR left a replica idle: {:?}",
                c.key(),
                c.routed
            );
        }
    }
}

/// Acceptance bar: fairness-aware routing strictly beats RoundRobin on
/// cluster-wide co-backlogged discrepancy for the heavy-hitter shape on
/// the heterogeneous fleet. RoundRobin ignores that the 40GB replicas
/// drain ~30% slower, so backlogs (and with them the victims' service
/// lag) pile up asymmetrically; FairShare balances predicted backlog
/// seconds per replica.
#[test]
fn fair_share_beats_round_robin_on_heavy_hitter_hetero() {
    use equinox::harness::cluster::cluster_trace;
    let seed = derive_seed(42, "heavy_hitter", "fs-vs-rr");
    // Cluster-scale load (2× fleet size), same trace both routers.
    let trace = cluster_trace("heavy_hitter", Fleet::hetero().len(), true, seed);
    let opts = ClusterOpts::new(seed);
    let run = |router: RouterKind| {
        run_cluster(
            Fleet::hetero(),
            router.make(),
            SchedKind::Equinox,
            PredKind::Mope,
            &trace,
            &opts,
        )
    };
    let rr = run(RouterKind::RoundRobin);
    let fs = run(RouterKind::FairShare);
    let (rr_disc, fs_disc) = (rr.max_co_backlogged_diff(), fs.max_co_backlogged_diff());
    assert!(rr_disc > 0.0, "heavy hitter must produce a co-backlogged gap under RR");
    assert!(
        fs_disc < rr_disc,
        "FairShare discrepancy {fs_disc:.0} must be strictly below RoundRobin {rr_disc:.0}"
    );
}

/// Sticky sessions: on a multi-turn workload FairShare keeps each
/// client's requests overwhelmingly on one replica (KV/prefix locality)
/// while RoundRobin scatters them by construction (~1/N per replica).
#[test]
fn fair_share_keeps_multi_turn_clients_sticky() {
    use equinox::core::ClientId;
    use std::collections::BTreeMap;

    let sc = adversarial::find("multi_turn").unwrap();
    let seed = derive_seed(42, sc.name, "sticky");
    let trace = sc.trace(true, seed);
    let opts = ClusterOpts::new(seed);
    // Fraction of requests landing on each client's dominant replica.
    let affinity = |router: RouterKind| {
        let res = run_cluster(
            Fleet::homogeneous(4),
            router.make(),
            SchedKind::Equinox,
            PredKind::Mope,
            &trace,
            &opts,
        );
        let mut per_client: BTreeMap<ClientId, Vec<usize>> = BTreeMap::new();
        for (ri, rep) in res.replicas.iter().enumerate() {
            for (c, lat) in rep.per_client_latency.iter() {
                per_client.entry(c).or_insert_with(|| vec![0; res.replicas.len()])[ri] +=
                    lat.count();
            }
        }
        let mut dominant = 0usize;
        let mut total = 0usize;
        for (_, counts) in per_client {
            dominant += counts.iter().copied().max().unwrap_or(0);
            total += counts.iter().sum::<usize>();
        }
        assert!(total > 0);
        dominant as f64 / total as f64
    };
    let fs = affinity(RouterKind::FairShare);
    let rr = affinity(RouterKind::RoundRobin);
    assert!(fs > 0.5, "FairShare affinity too weak: {fs:.2}");
    assert!(fs > 1.5 * rr, "FairShare {fs:.2} must clearly beat RoundRobin {rr:.2}");
}

/// The KV-headroom property at the cluster level: on the skewed fleet
/// (one healthy replica + KV-starved ones) FairShare still drains
/// everything without violating conservation, and routes the bulk of the
/// work where the KV actually is.
#[test]
fn fair_share_respects_kv_headroom_on_skewed_fleet() {
    let sc = adversarial::find("constant_overload").unwrap();
    let seed = derive_seed(42, sc.name, "skewed");
    let trace = sc.trace(true, seed);
    let opts = ClusterOpts::new(seed);
    let res = run_cluster(
        Fleet::skewed(3),
        RouterKind::FairShare.make(),
        SchedKind::Equinox,
        PredKind::Mope,
        &trace,
        &opts,
    );
    assert_eq!(res.finished(), res.total_requests());
    // The healthy 80GB replica (id 0) must carry the largest share —
    // the starved replicas simply cannot hold the hot set.
    assert!(
        res.routed[0] >= *res.routed[1..].iter().max().unwrap(),
        "healthy replica must carry the most work: {:?}",
        res.routed
    );
}

/// Global rollups are consistent with per-replica results.
#[test]
fn cluster_rollups_are_consistent() {
    let sc = adversarial::find("flash_crowd").unwrap();
    let seed = 7;
    let trace = sc.trace(true, seed);
    let opts = ClusterOpts::new(seed);
    let res = run_cluster(
        Fleet::hetero(),
        RouterKind::PredictedCost.make(),
        SchedKind::Equinox,
        PredKind::Mope,
        &trace,
        &opts,
    );
    let per_replica: f64 = res.replicas.iter().map(|r| r.service.grand_total()).sum();
    assert!((res.grand_service() - per_replica).abs() < 1e-9);
    let lat = res.merged_latency();
    let counts: usize = res.replicas.iter().map(|r| r.latency.count()).sum();
    assert_eq!(lat.count(), counts);
    assert!(res.wall() >= res.replicas.iter().map(|r| r.wall).fold(0.0, f64::max) - 1e-12);
    let jain = res.jain_over_service();
    assert!((0.0..=1.0 + 1e-9).contains(&jain));
}
