//! Integration tests over the full simulator stack: workload → predictor
//! → scheduler → engine → metrics, checking the cross-module claims the
//! paper's evaluation depends on.

use equinox::core::ClientId;
use equinox::exp::{run_sim, ExpOpts, PredKind, SchedKind};
use equinox::metrics::fairness::summarize_diffs;
use equinox::sim::{HostProfile, SimConfig};
use equinox::workload::tracegen::mixed_tenants_trace;
use equinox::workload::{generate, Scenario};

fn slora_cfg() -> SimConfig {
    SimConfig::a100_7b_vllm().with_host(HostProfile::SLORA)
}

#[test]
fn all_schedulers_complete_all_workloads() {
    for scenario in [
        Scenario::balanced_load(40.0),
        Scenario::stochastic_arrivals(25.0),
        Scenario::constant_overload(20.0),
        Scenario::dynamic_load(40.0),
    ] {
        let trace = generate(&scenario, 11);
        for sched in [SchedKind::Fcfs, SchedKind::Rpm, SchedKind::Vtc, SchedKind::Equinox] {
            let res = run_sim(&slora_cfg(), sched, PredKind::Mope, &trace, 11);
            assert_eq!(
                res.finished,
                trace.len(),
                "{} lost requests on {}",
                sched.label(),
                scenario.name
            );
        }
    }
}

#[test]
fn fair_schedulers_bound_service_gap_under_overload() {
    let trace = generate(&Scenario::constant_overload(60.0), 5);
    let fcfs = run_sim(&slora_cfg(), SchedKind::Fcfs, PredKind::Oracle, &trace, 5);
    let vtc = run_sim(&slora_cfg(), SchedKind::Vtc, PredKind::Oracle, &trace, 5);
    let eqx = run_sim(&slora_cfg(), SchedKind::Equinox, PredKind::Mope, &trace, 5);
    let gap = |r: &equinox::sim::SimResult| {
        summarize_diffs(&r.backlogged_diff_series(ClientId(0), ClientId(1))).avg
    };
    let (gf, gv, ge) = (gap(&fcfs), gap(&vtc), gap(&eqx));
    assert!(gv < gf, "VTC {gv} must beat FCFS {gf}");
    assert!(ge < gf, "Equinox {ge} must beat FCFS {gf}");
}

#[test]
fn equinox_outperforms_vtc_on_throughput_under_overload() {
    // The paper's headline: up to 1.3× throughput via stall-free
    // scheduling + adaptive batching (Fig 17 / §7.2).
    let trace = generate(&Scenario::constant_overload(60.0), 7);
    let vtc = run_sim(&slora_cfg(), SchedKind::Vtc, PredKind::Oracle, &trace, 7);
    let eqx = run_sim(&slora_cfg(), SchedKind::Equinox, PredKind::Mope, &trace, 7);
    let ratio = eqx.weighted_tps / vtc.weighted_tps;
    assert!(ratio > 1.05, "Equinox/VTC throughput ratio = {ratio:.3}, want > 1.05");
    assert!(
        eqx.preemptions < vtc.preemptions,
        "stall-free must reduce preemptions: {} vs {}",
        eqx.preemptions,
        vtc.preemptions
    );
}

#[test]
fn prediction_quality_orders_fairness() {
    // Table 1's core claim: better predictions → tighter fairness for the
    // predictive schedulers.
    let trace = generate(&Scenario::stochastic_arrivals(60.0), 13);
    let gap = |pred: PredKind| {
        let r = run_sim(&slora_cfg(), SchedKind::VtcPred, pred, &trace, 13);
        summarize_diffs(&r.backlogged_diff_series(ClientId(0), ClientId(1))).avg
    };
    let single = gap(PredKind::Single);
    let mope = gap(PredKind::Mope);
    let oracle = gap(PredKind::Oracle);
    assert!(
        mope < single * 1.05,
        "MoPE ({mope}) should be no worse than Single ({single})"
    );
    assert!(
        mope < 3.0 * oracle + 1000.0,
        "MoPE ({mope}) should approach Oracle ({oracle})"
    );
}

#[test]
fn utilization_stays_high_under_load() {
    // §1/§7: Equinox maintains ~94% GPU utilization under load.
    let trace = generate(&Scenario::constant_overload(40.0), 3);
    let res = run_sim(&slora_cfg(), SchedKind::Equinox, PredKind::Mope, &trace, 3);
    assert!(res.gpu_util > 0.7, "util={}", res.gpu_util);
}

#[test]
fn heterogeneous_tenants_get_comparable_service_under_equinox() {
    let trace = mixed_tenants_trace(2, 120.0, 17);
    let res = run_sim(&SimConfig::a100_7b_vllm(), SchedKind::Equinox, PredKind::Mope, &trace, 17);
    let totals: Vec<f64> =
        res.service.clients().iter().map(|c| res.service.total(*c)).collect();
    let max = totals.iter().cloned().fold(f64::MIN, f64::max);
    let min = totals.iter().cloned().fold(f64::MAX, f64::min);
    assert!(max / min < 2.0, "service spread too wide: {totals:?}");
}

#[test]
fn experiment_registry_runs_quick() {
    // Every experiment must at least run and produce a table in quick
    // mode (the deep checks live in each experiment's unit tests).
    let opts = ExpOpts::quick();
    for e in equinox::exp::registry() {
        let out = (e.run)(&opts);
        assert!(out.contains('|'), "{} produced no table:\n{out}", e.id);
    }
}

#[test]
fn rpm_wastes_capacity_offpeak() {
    // §1's RPM critique: static quotas idle the GPU even with queued work.
    let trace = generate(&Scenario::balanced_load(60.0), 19);
    let mut quota_sched = equinox::sched::Rpm::new(30, 60.0); // 30 rpm ≪ demand
    let mut oracle = equinox::predictor::Oracle::new();
    let mut sim = equinox::sim::Simulation::new(slora_cfg(), &mut quota_sched, &mut oracle);
    let rpm = sim.run(&trace);
    let fcfs = run_sim(&slora_cfg(), SchedKind::Fcfs, PredKind::Oracle, &trace, 19);
    assert!(
        rpm.weighted_tps < 0.7 * fcfs.weighted_tps,
        "RPM should throttle: {} vs {}",
        rpm.weighted_tps,
        fcfs.weighted_tps
    );
}
