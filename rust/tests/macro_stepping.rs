//! Differential tests: the event-horizon macro-stepping engine must be a
//! pure performance transformation of the per-token reference. Both modes
//! share one loop — only the advance step differs — so any divergence in
//! finished/preemptions/service/latency is a bug in the event-horizon
//! computation. Tolerances: integers exact; times within 1e-9 relative
//! (the macro path sums iteration costs in closed form, which differs
//! from serial summation only in float rounding); windowed-rate fairness
//! within the one-token ramp-vs-staircase band (EXPERIMENTS.md §Perf).

use equinox::core::ClientId;
use equinox::exp::{run_sim_stepped, PredKind, SchedKind};
use equinox::predictor::Oracle;
use equinox::sched::Fcfs;
use equinox::sim::{HostProfile, SimConfig, SimResult, Simulation, StepMode};
use equinox::workload::{generate, Scenario, Trace};

fn close(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * a.abs().max(b.abs()).max(1.0)
}

/// The acceptance contract: identical integer outcomes, float aggregates
/// within 1e-9 relative, windowed fairness within the one-token
/// ramp-vs-staircase band. The contract itself lives in ONE place —
/// `harness::compare_modes` — shared with the conformance matrix, so the
/// differential suite and the matrix can never enforce different
/// equivalence definitions.
fn assert_equivalent(micro: &SimResult, mac: &SimResult, label: &str) {
    let violations = equinox::harness::compare_modes(micro, mac);
    assert!(violations.is_empty(), "{label}:\n  {}", violations.join("\n  "));
}

fn both(cfg: &SimConfig, sched: SchedKind, pred: PredKind, trace: &Trace) -> (SimResult, SimResult) {
    let micro = run_sim_stepped(cfg, StepMode::Micro, sched, pred, trace, 42);
    let mac = run_sim_stepped(cfg, StepMode::Macro, sched, pred, trace, 42);
    (micro, mac)
}

#[test]
fn macro_equals_micro_across_schedulers_and_scenarios() {
    let cfg = SimConfig::a100_7b_vllm();
    for (scenario, label) in [
        (Scenario::balanced_load(20.0), "balanced"),
        (Scenario::stochastic_arrivals(12.0), "stochastic"),
    ] {
        let trace = generate(&scenario, 42);
        for sched in [SchedKind::Fcfs, SchedKind::Vtc, SchedKind::Equinox] {
            let pred =
                if sched == SchedKind::Equinox { PredKind::Mope } else { PredKind::Oracle };
            let (micro, mac) = both(&cfg, sched, pred, &trace);
            assert!(mac.macro_steps > 0, "{label}/{sched:?}: no macro-steps taken");
            assert!(
                mac.iterations < micro.iterations,
                "{label}/{sched:?}: macro {} vs micro {}",
                mac.iterations,
                micro.iterations
            );
            assert_equivalent(&micro, &mac, &format!("{label}/{sched:?}"));
        }
    }
}

#[test]
fn macro_equals_micro_on_adversarial_scenarios() {
    // The adversarial shapes most likely to break the event-horizon `k`
    // computation: flash_crowd's spike drops a burst of arrivals inside
    // what would otherwise be one long decode window (the arrival bound
    // must clip `k` exactly), tenant_churn's joins/leaves flip the
    // backlog set between windows, and diurnal's sinusoid produces
    // constantly-shifting batch compositions.
    let cfg = SimConfig::a100_7b_vllm();
    for name in ["flash_crowd", "tenant_churn", "diurnal"] {
        let sc = equinox::workload::adversarial::find(name).unwrap();
        let trace = sc.trace(true, 11);
        for sched in [SchedKind::Fcfs, SchedKind::Vtc, SchedKind::Equinox] {
            let pred = if sched == SchedKind::Equinox { PredKind::Mope } else { PredKind::Oracle };
            let (micro, mac) = both(&cfg, sched, pred, &trace);
            assert!(mac.macro_steps > 0, "{name}/{sched:?}: no macro-steps taken");
            assert_equivalent(&micro, &mac, &format!("{name}/{sched:?}"));
        }
    }
}

#[test]
fn macro_equals_micro_under_rpm_quota_refreshes() {
    // RPM is the one policy whose admissibility changes with wall time —
    // the scheduler's `next_refresh_at` hint must bound macro windows so
    // quota refreshes land on the same iteration boundary in both modes.
    let cfg = SimConfig::a100_7b_vllm();
    let trace = generate(&Scenario::balanced_load(20.0), 42);
    let (micro, mac) = both(&cfg, SchedKind::Rpm, PredKind::Oracle, &trace);
    assert_equivalent(&micro, &mac, "rpm");
}

#[test]
fn macro_equals_micro_with_preemptions_mid_window() {
    // Tight KV pool + prediction-blind VTC under overload: free pages
    // run out mid-decode, so the event horizon must stop exactly at the
    // exhaustion point and let the shared preemption path fire — both
    // modes must preempt the same victims at the same times.
    let mut host = HostProfile::SLORA;
    host.kv_fraction = 0.08;
    let cfg = SimConfig::a100_7b_vllm().with_host(host);
    let trace = generate(&Scenario::constant_overload(20.0), 7);
    let (micro, mac) = both(&cfg, SchedKind::Vtc, PredKind::Oracle, &trace);
    assert!(micro.preemptions > 0, "setup must preempt to exercise the KV event horizon");
    assert_equivalent(&micro, &mac, "preemption");
    assert_eq!(mac.rework_live, 0, "rework watermarks must drain on completion");
}

#[test]
fn macro_equals_micro_with_sample_windows_inside_steps() {
    // A sample period much shorter than a natural macro window: every
    // window boundary lands inside what would otherwise be one step. The
    // boundary is an event — util/backlog sampling must see identical
    // window sums in both modes.
    let mut cfg = SimConfig::a100_7b_vllm();
    cfg.sample_dt = 0.05;
    let trace = generate(&Scenario::balanced_load(10.0), 42);
    let (micro, mac) = both(&cfg, SchedKind::Fcfs, PredKind::Oracle, &trace);
    assert_equivalent(&micro, &mac, "sampling");
    assert_eq!(micro.util_timeline.len(), mac.util_timeline.len(), "window counts");
    for (i, ((tm, um), (ta, ua))) in
        micro.util_timeline.iter().zip(mac.util_timeline.iter()).enumerate()
    {
        assert!(close(*tm, *ta, 1e-9), "window {i} time {tm} vs {ta}");
        assert!((um - ua).abs() < 1e-6, "window {i} util {um} vs {ua}");
    }
    assert_eq!(micro.backlog_timeline.len(), mac.backlog_timeline.len());
    for (i, ((_, bm), (_, ba))) in
        micro.backlog_timeline.iter().zip(mac.backlog_timeline.iter()).enumerate()
    {
        assert_eq!(bm[..], ba[..], "window {i} backlog sets");
    }
}

#[test]
fn macro_handles_zero_output_requests() {
    // Zero-output requests complete straight out of prefill and never
    // enter a decode window; interleaved with normal traffic they must
    // not wedge or skew either mode.
    let mut events = Vec::new();
    for i in 0..30 {
        let t = i as f64 * 0.4;
        events.push((t, ClientId(0), 64, if i % 3 == 0 { 0 } else { 96 }));
        events.push((t + 0.1, ClientId(1), 32, 128));
    }
    let trace = Trace::from_events(events, 12.0);
    let cfg = SimConfig::a100_7b_vllm();
    let (micro, mac) = both(&cfg, SchedKind::Fcfs, PredKind::Oracle, &trace);
    assert_eq!(mac.finished, trace.len(), "all requests (incl. zero-output) must finish");
    assert_equivalent(&micro, &mac, "zero-output");
}

#[test]
fn single_request_kv_corner_stalls_identically() {
    // One request whose full context cannot fit in the pool: the memory
    // assurance cannot preempt (batch of one), growth fails, and the
    // engine stalls until the iteration cap. The macro engine must fall
    // back to per-token stepping at the exhaustion point (safe window of
    // zero) and reproduce the stall, not spin or panic.
    let mut host = HostProfile::VLLM;
    host.kv_fraction = 0.002; // ≈ 240 tokens of KV
    let trace = Trace::from_events(vec![(0.0, ClientId(0), 64, 4096)], 1.0);
    let run = |mode: StepMode| {
        let mut cfg = SimConfig::a100_7b_vllm().with_host(host);
        cfg.step_mode = mode;
        cfg.max_iterations = 3000;
        let mut sched = Fcfs::new();
        let mut pred = Oracle::new();
        let mut sim = Simulation::new(cfg, &mut sched, &mut pred);
        sim.run(&trace)
    };
    let micro = run(StepMode::Micro);
    let mac = run(StepMode::Macro);
    for (mode, res) in [("micro", &micro), ("macro", &mac)] {
        assert_eq!(res.finished, 0, "{mode}: the request cannot complete");
        assert_eq!(res.preemptions, 0, "{mode}: a batch of one has no victim");
        assert!(res.iterations >= 3000, "{mode}: must run to the iteration cap, not exit early");
        assert!(res.wall > 0.0, "{mode}: stalled iterations still advance the clock");
    }
    // The macro engine compresses the pre-exhaustion decode phase, then
    // stalls per-token exactly like the reference (a safe window of zero
    // forces micro-steps) — so under the same loop-iteration cap it
    // spends at least as many token-equivalents as the reference.
    assert!(mac.iter_equiv >= micro.iter_equiv);
}
