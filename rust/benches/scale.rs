//! Million-tenant scale benchmarks: dense `ClientSlab` storage vs the
//! `BTreeMap` reference family, C ∈ {10k, 100k, 1M}.
//!
//! Two measurements per (family, C) cell, both through the SAME generic
//! code paths the production schedulers run (`ClientMapFamily` picks the
//! storage):
//!
//! - `admit+credit+pick` — one full fairness cycle on
//!   `HolisticCounters<F>`: charge UFC+RFC at admission, (re)activation
//!   lift + index insert, argmin-HF pick, deactivate. This is the per-
//!   request hot path of the Equinox scheduler with C tenants resident.
//! - `probe` — a single `or_default` counter bump on a C-entry map, the
//!   primitive every admit/credit touches several times.
//!
//! The run prints slab-vs-btreemap speedup lines per scale plus the
//! slab's bytes-per-idle-tenant, and dumps everything to
//! `BENCH_scale.json` so the scaling trajectory is tracked across PRs
//! (see EXPERIMENTS.md §Scale). `EQUINOX_BENCH_QUICK=1` switches to the
//! CI-budget sample settings.

use equinox::core::{
    BTreeFamily, ClientId, ClientMap, ClientMapFamily, ClientSlab, Request, RequestId, SlabFamily,
};
use equinox::sched::{HfParams, HolisticCounters};
use equinox::util::bench::{black_box, Bench};
use equinox::util::json::Json;

const SCALES: [u32; 3] = [10_000, 100_000, 1_000_000];

fn template() -> Request {
    let mut r = Request::new(RequestId(0), ClientId(0), 64, 64, 0.0);
    r.predicted_output_tokens = 64;
    r.predicted_latency = 1.0;
    r.predicted_tps = 1000.0;
    r.predicted_gpu_util = 0.8;
    r
}

/// One admission-to-pick fairness cycle per iteration, rotating through
/// all C tenants so every probe lands on a different (cold) slot — the
/// storage family is the only variable.
fn bench_counters<F: ClientMapFamily>(b: &mut Bench, clients: u32) {
    let mut hc: HolisticCounters<F> = HolisticCounters::new(HfParams::default());
    for c in 0..clients {
        hc.touch(ClientId(c), 1.0);
    }
    let mut req = template();
    let mut next = 0u32;
    b.run(&format!("{}/admit+credit+pick/{clients}c", F::LABEL), || {
        let c = ClientId(next);
        next += 1;
        if next == clients {
            next = 0;
        }
        req.client = c;
        hc.charge_admission(&req, 1.0, 1000.0);
        if !hc.is_active(c) {
            hc.lift_to_active_min_indexed(c);
            hc.set_active(c);
        }
        let winner = hc.argmin_hf_active().expect("active set is non-empty");
        hc.set_inactive(winner);
        black_box(winner)
    });
}

/// The raw per-tenant state probe (`or_default` bump) on a C-entry map.
fn bench_probe<F: ClientMapFamily>(b: &mut Bench, clients: u32) {
    let mut map: F::Map<f64> = Default::default();
    for c in 0..clients {
        *map.or_default(ClientId(c)) += 1.0;
    }
    let mut next = 0u32;
    b.run(&format!("{}/probe/{clients}c", F::LABEL), || {
        let c = ClientId(next);
        next += 1;
        if next == clients {
            next = 0;
        }
        *map.or_default(c) += 1.0;
        black_box(next)
    });
}

fn report_speedup(b: &Bench, kind: &str, clients: u32) -> Option<f64> {
    let get = |fam: &str| {
        let name = format!("{fam}/{kind}/{clients}c");
        b.results.iter().find(|(n, _)| n == &name).map(|(_, v)| *v)
    };
    let (slab, btree) = (get("slab")?, get("btree")?);
    let speedup = btree / slab.max(1e-9);
    println!(
        "speedup {kind}@{clients}c: {speedup:.1}x (slab {slab:.0} ns vs btreemap {btree:.0} ns)"
    );
    Some(speedup)
}

/// Resident bytes per tenant for the slab layout at population C, using
/// the Equinox counter payload (ufc, rfc, weight). Dense storage makes
/// this a closed-form number the bench can attest per run.
fn slab_bytes_per_idle_tenant(clients: u32) -> f64 {
    let mut slab: ClientSlab<[f64; 3]> = ClientSlab::with_capacity(clients as usize);
    for c in 0..clients {
        slab.or_default(ClientId(c));
    }
    slab.bytes_resident() as f64 / clients as f64
}

fn main() {
    let mut b = Bench::from_args();
    if std::env::var_os("EQUINOX_BENCH_QUICK").is_some() {
        b = b.quick();
    }
    for &clients in &SCALES {
        bench_counters::<SlabFamily>(&mut b, clients);
        bench_counters::<BTreeFamily>(&mut b, clients);
        bench_probe::<SlabFamily>(&mut b, clients);
        bench_probe::<BTreeFamily>(&mut b, clients);
    }

    let mut obj = Json::obj();
    for (name, ns) in &b.results {
        obj = obj.set(name, *ns);
    }
    for &clients in &SCALES {
        for kind in ["admit+credit+pick", "probe"] {
            if let Some(s) = report_speedup(&b, kind, clients) {
                obj = obj.set(&format!("speedup/{kind}/{clients}c"), s);
            }
        }
        let bytes = slab_bytes_per_idle_tenant(clients);
        println!("slab bytes/idle-tenant @{clients}c: {bytes:.1}");
        obj = obj.set(&format!("slab_bytes_per_idle_tenant/{clients}c"), bytes);
    }
    match std::fs::write("BENCH_scale.json", obj.to_string()) {
        Ok(()) => println!("wrote BENCH_scale.json ({} entries)", b.results.len()),
        Err(e) => eprintln!("BENCH_scale.json not written: {e}"),
    }
}
