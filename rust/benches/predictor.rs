//! Predictor microbenchmarks: MoPE routing + prediction must be
//! negligible next to the modelled 4.5 ms expert forward pass, and the
//! PerfMap lookup sits on the per-arrival path.

use equinox::core::{ClientId, Request, RequestId};
use equinox::predictor::{MoPE, Oracle, PerfMap, Predictor, SingleProxy};
use equinox::util::bench::{black_box, Bench};
use equinox::util::rng::Rng;

fn main() {
    let mut b = Bench::from_args();
    let mut rng = Rng::new(3);
    let reqs: Vec<Request> = (0..1024)
        .map(|i| {
            Request::new(
                RequestId(i),
                ClientId((i % 8) as u32),
                rng.range(8, 1024) as u32,
                rng.range(8, 1024) as u32,
                0.0,
            )
        })
        .collect();

    let mut oracle = Oracle::new();
    let mut i = 0usize;
    b.run("oracle/predict", || {
        i = (i + 1) % reqs.len();
        black_box(oracle.predict_tokens(&reqs[i]))
    });

    let mut single = SingleProxy::new(5);
    b.run("single/predict", || {
        i = (i + 1) % reqs.len();
        black_box(single.predict_tokens(&reqs[i]))
    });

    let mut mope = MoPE::new(5);
    b.run("mope/predict", || {
        i = (i + 1) % reqs.len();
        black_box(mope.predict_tokens(&reqs[i]))
    });

    let pm = PerfMap::default_a100_7b();
    b.run("perfmap/map", || {
        i = (i + 1) % reqs.len();
        black_box(pm.map(reqs[i].input_tokens, reqs[i].true_output_tokens))
    });

    let mut pm = PerfMap::default_a100_7b();
    let obs = pm.map(100, 100);
    b.run("perfmap/observe", || {
        pm.observe(100, 100, obs);
        black_box(pm.len())
    });
}
