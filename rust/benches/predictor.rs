//! Predictor microbenchmarks: MoPE routing + prediction must be
//! negligible next to the modelled 4.5 ms expert forward pass, and the
//! PerfMap lookup sits on the per-arrival path. The guard section pins
//! the calibration tracker's per-completion update and the debiased
//! admission charge against the raw (unguarded) cast they replace —
//! both sit on the scheduler hot path, so the medians land in
//! `BENCH_predictor.json` for cross-run diffing.

use equinox::core::{ClientId, Request, RequestId};
use equinox::predictor::{MoPE, Oracle, PerfMap, Predictor, SingleProxy};
use equinox::sched::{CalibrationTracker, GuardPolicy};
use equinox::util::bench::{black_box, Bench};
use equinox::util::json::Json;
use equinox::util::rng::Rng;

fn main() {
    let mut b = Bench::from_args();
    let mut rng = Rng::new(3);
    let reqs: Vec<Request> = (0..1024)
        .map(|i| {
            Request::new(
                RequestId(i),
                ClientId((i % 8) as u32),
                rng.range(8, 1024) as u32,
                rng.range(8, 1024) as u32,
                0.0,
            )
        })
        .collect();

    let mut oracle = Oracle::new();
    let mut i = 0usize;
    b.run("oracle/predict", || {
        i = (i + 1) % reqs.len();
        black_box(oracle.predict_tokens(&reqs[i]))
    });

    let mut single = SingleProxy::new(5);
    b.run("single/predict", || {
        i = (i + 1) % reqs.len();
        black_box(single.predict_tokens(&reqs[i]))
    });

    let mut mope = MoPE::new(5);
    b.run("mope/predict", || {
        i = (i + 1) % reqs.len();
        black_box(mope.predict_tokens(&reqs[i]))
    });

    let pm = PerfMap::default_a100_7b();
    b.run("perfmap/map", || {
        i = (i + 1) % reqs.len();
        black_box(pm.map(reqs[i].input_tokens, reqs[i].true_output_tokens))
    });

    let mut pm = PerfMap::default_a100_7b();
    let obs = pm.map(100, 100);
    b.run("perfmap/observe", || {
        pm.observe(100, 100, obs);
        black_box(pm.len())
    });

    // ---- calibration guard overhead (sched/guard.rs) ----
    // The raw baseline the guard replaces: the unguarded admission
    // charge is a plain integer→float cast of the prediction.
    let mut p = 0u32;
    b.run("guard/charge/raw-cast", || {
        p = p.wrapping_add(37) % 1024;
        black_box(p as f64)
    });

    // Per-completion tracker update at 10k distinct clients: regime
    // EWMA + slab-backed per-client cell + (cheap) ladder step.
    let mut tracker = CalibrationTracker::new(GuardPolicy::Ladder);
    for c in 0..10_000u32 {
        tracker.observe(ClientId(c), 64 + c % 512, 64 + (c * 7) % 512);
    }
    let mut c = 0u32;
    b.run("guard/observe@10k-clients", || {
        c = (c + 1) % 10_000;
        tracker.observe(ClientId(c), 64 + c % 512, 64 + (c * 7) % 512);
        black_box(tracker.mode())
    });

    // Admission charge, predictive rung: must be nothing but the cast
    // behind a match (the bitwise no-op arm the Oracle property pins).
    let fresh = CalibrationTracker::new(GuardPolicy::Ladder);
    b.run("guard/charge/predictive", || {
        p = p.wrapping_add(37) % 1024;
        black_box(fresh.charged_tokens(p))
    });

    // Admission charge, debiased rung with a seasoned 2x-bias tracker:
    // regime lookup + exp + clamp on every admit.
    let mut biased = CalibrationTracker::new(GuardPolicy::Debias);
    for c in 0..10_000u32 {
        let actual = 16 + c % 256;
        biased.observe(ClientId(c), actual * 2, actual);
    }
    b.run("guard/charge/debiased@10k-clients", || {
        p = p.wrapping_add(37) % 1024;
        black_box(biased.charged_tokens(p))
    });

    // Machine-readable trajectory: name → median ns/op.
    let mut obj = Json::obj();
    for (name, ns) in &b.results {
        obj = obj.set(name, *ns);
    }
    match std::fs::write("BENCH_predictor.json", obj.to_string()) {
        Ok(()) => println!("wrote BENCH_predictor.json ({} entries)", b.results.len()),
        Err(e) => eprintln!("BENCH_predictor.json not written: {e}"),
    }
}
