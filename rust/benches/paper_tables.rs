//! Regenerates every paper table/figure in quick mode and times each —
//! `cargo bench --bench paper_tables`. For publication-scale outputs run
//! `equinox exp all` (no --quick) instead; EXPERIMENTS.md records those.

use equinox::exp::{registry, ExpOpts};

fn main() {
    let filter = std::env::args().skip(1).find(|a| !a.starts_with('-'));
    let opts = ExpOpts::quick();
    let mut total = 0.0;
    for e in registry() {
        if let Some(f) = &filter {
            if !e.id.contains(f.as_str()) {
                continue;
            }
        }
        let t = std::time::Instant::now();
        let out = (e.run)(&opts);
        let dt = t.elapsed().as_secs_f64();
        total += dt;
        // Keep bench output compact: id, timing, and the first table row
        // as a liveness check.
        let first_row = out.lines().find(|l| l.starts_with('|')).unwrap_or("");
        println!("bench paper/{:<8} {dt:>8.2} s   {first_row}", e.id);
    }
    println!("bench paper/total {total:>10.2} s");
}
