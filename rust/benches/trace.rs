//! Flight-recorder overhead benchmarks: the same cluster cell with the
//! recorder off (NullRecorder default), on (ring TraceRecorder), and on
//! with a tiny always-overflowing ring. Acceptance: TraceRecorder ≤5%
//! wall-clock overhead, NullRecorder indistinguishable from the
//! pre-recorder baseline. Also measures raw `record()` + drain/merge
//! cost per event. Results land in `BENCH_trace.json`
//! (EXPERIMENTS.md §Observability).

use equinox::cluster::{run_cluster, ClusterOpts, DriveMode, Fleet, ReplicaSpec, RouterKind};
use equinox::core::{ClientId, RequestId};
use equinox::exp::{PredKind, SchedKind};
use equinox::obs::{merge_events, trace_digest, EventKind, Recorder, TraceCfg, TraceRecorder};
use equinox::util::bench::{black_box, Bench};
use equinox::util::json::Json;
use equinox::workload::{generate, Scenario, Trace};

fn bench_fleet(n: usize) -> Fleet {
    Fleet { name: format!("bench{n}"), replicas: (0..n).map(|_| ReplicaSpec::a100_40g()).collect() }
}

/// Wall-clock one full cluster run (ns), best of up to 3 within a ~1.5 s
/// budget (same protocol as benches/cluster.rs).
fn cluster_wall_ns(n: usize, trace: &Trace, trace_cfg: Option<TraceCfg>) -> f64 {
    let mut best = f64::INFINITY;
    let mut spent = 0.0f64;
    for _ in 0..3 {
        let t = std::time::Instant::now();
        let mut opts = ClusterOpts::new(42).with_drive(DriveMode::Serial);
        if let Some(tc) = trace_cfg {
            opts = opts.with_trace(tc);
        }
        let res = run_cluster(
            bench_fleet(n),
            RouterKind::FairShare.make(),
            SchedKind::Equinox,
            PredKind::Mope,
            trace,
            &opts,
        );
        black_box(res.finished());
        black_box(res.trace.as_ref().map(|l| l.events.len()));
        let ns = t.elapsed().as_nanos() as f64;
        best = best.min(ns);
        spent += ns;
        if spent > 1.5e9 {
            break;
        }
    }
    best
}

fn main() {
    let mut b = Bench::from_args().quick();

    // ---- recorder on/off end-to-end overhead ----
    // Identical (trace, fleet, router, seed) cell three ways. The ratios
    // are the cross-PR trajectory lines and the acceptance bars:
    // recorder-on ≤1.05x, recorder-off ≈1.00x (no measurable cost).
    for n in [4usize, 16] {
        let trace = generate(&Scenario::balanced_load(6.0).scale_rates(n as f64), 42);
        let off_ns = cluster_wall_ns(n, &trace, None);
        let on_ns = cluster_wall_ns(n, &trace, Some(TraceCfg::default()));
        let tiny_ns = cluster_wall_ns(n, &trace, Some(TraceCfg { capacity: 256 }));
        let on_ratio = on_ns / off_ns.max(1.0);
        let tiny_ratio = tiny_ns / off_ns.max(1.0);
        b.results.push((format!("trace/n{n}/recorder-off"), off_ns));
        b.results.push((format!("trace/n{n}/recorder-on"), on_ns));
        b.results.push((format!("trace/n{n}/recorder-on-tiny-ring"), tiny_ns));
        b.results.push((format!("trace/n{n}/overhead"), on_ratio));
        b.results.push((format!("trace/n{n}/overhead-tiny-ring"), tiny_ratio));
        println!(
            "recorder n={n}: off {:.1} ms, on {:.1} ms ({on_ratio:.3}x), tiny ring {:.1} ms ({tiny_ratio:.3}x)",
            off_ns / 1e6,
            on_ns / 1e6,
            tiny_ns / 1e6
        );
    }

    // ---- raw record() cost ----
    // The per-event hot-path price: one ring write, no allocation. The
    // NullRecorder line is the price of the virtual no-op call the rare
    // (unconditional) record sites pay when tracing is off.
    let ev = EventKind::Progress { client: ClientId(7), tokens: 64.0, running: 32 };
    {
        let mut rec = TraceRecorder::new(0, 1 << 16);
        let mut t = 0.0f64;
        b.run("trace/record/ring", || {
            t += 1e-6;
            rec.record(t, ev);
            black_box(rec.len())
        });
    }
    {
        let mut null = equinox::obs::NullRecorder;
        let rec: &mut dyn Recorder = &mut null;
        let mut t = 0.0f64;
        b.run("trace/record/null-dyn", || {
            t += 1e-6;
            rec.record(t, ev);
            black_box(rec.enabled())
        });
    }

    // ---- drain + merge + digest cost per 64k events ----
    {
        let mut out = Vec::new();
        b.run("trace/drain-merge-digest/64k", || {
            let mut rec = TraceRecorder::new(0, 1 << 16);
            for i in 0..(1u32 << 16) {
                rec.record(
                    i as f64 * 1e-6,
                    EventKind::Arrive { client: ClientId(i % 512), req: RequestId(i as u64) },
                );
            }
            out.clear();
            rec.drain_into(&mut out);
            merge_events(&mut out);
            black_box(trace_digest(&out))
        });
    }

    // Machine-readable trajectory: name → median ns/op (ratios stored
    // as plain numbers).
    let mut obj = Json::obj();
    for (name, ns) in &b.results {
        obj = obj.set(name, *ns);
    }
    match std::fs::write("BENCH_trace.json", obj.to_string()) {
        Ok(()) => println!("wrote BENCH_trace.json ({} entries)", b.results.len()),
        Err(e) => eprintln!("BENCH_trace.json not written: {e}"),
    }
}
