//! End-to-end simulator throughput: simulated requests per wall-second —
//! the number that bounds how fast the paper-table harness runs. The
//! §Perf pass optimises this loop.

use equinox::exp::{run_sim, PredKind, SchedKind};
use equinox::sim::{HostProfile, SimConfig};
use equinox::util::bench::Bench;
use equinox::workload::{generate, Scenario};

fn main() {
    let mut b = Bench::from_args().quick();
    let trace = generate(&Scenario::balanced_load(60.0), 42);
    let n = trace.len() as u64;
    let cfg = SimConfig::a100_7b_vllm().with_host(HostProfile::SLORA);

    for (name, sched, pred) in [
        ("sim/fcfs+oracle", SchedKind::Fcfs, PredKind::Oracle),
        ("sim/vtc+oracle", SchedKind::Vtc, PredKind::Oracle),
        ("sim/equinox+mope", SchedKind::Equinox, PredKind::Mope),
    ] {
        b.run_throughput(name, n, || {
            let r = run_sim(&cfg, sched, pred, &trace, 42);
            assert_eq!(r.finished, trace.len());
        });
    }

    // GPU cost model alone (varying input so the optimiser can't fold it).
    let gpu = equinox::sim::GpuModel::a100_7b();
    let mut ctx = 0u64;
    b.run("gpu_model/iteration", || {
        ctx = (ctx + 17) % 2048;
        let mix = equinox::sim::gpu::IterationMix {
            prefill_tokens: 256 + ctx % 512,
            prefill_context: 4 * ctx,
            decode_seqs: 1 + ctx % 128,
            decode_context: (1 + ctx % 128) * (256 + ctx),
        };
        equinox::util::bench::black_box(gpu.iteration(&mix).time)
    });
}
