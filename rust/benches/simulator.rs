//! End-to-end simulator throughput: simulated requests per wall-second —
//! the number that bounds how fast the paper-table harness runs. The
//! §Perf pass optimises this loop; since the macro-stepping PR the
//! headline measurement is macro vs per-token (micro) engine mode on a
//! decode-heavy long-output trace, where the event horizon collapses
//! thousands of per-token iterations into one step per scheduling event.
//!
//! Prints `speedup sim/...` lines (wall-clock and engine-iteration
//! ratios) and writes **`BENCH_simulator.json`** (flat name → value,
//! same shape as `BENCH_scheduler.json`) for cross-PR perf tracking.

use equinox::exp::{run_sim, run_sim_stepped, PredKind, SchedKind};
use equinox::sim::{HostProfile, SimConfig, StepMode};
use equinox::util::bench::Bench;
use equinox::util::json::Json;
use equinox::workload::{generate, Arrival, ArrivalProcess, ClientSpec, Scenario};

/// Long-output decode-heavy workload: few arrivals, outputs in the
/// thousands of tokens — the regime where per-token stepping pays ~10⁵
/// engine iterations per run and macro-stepping pays one per event.
fn decode_heavy() -> Scenario {
    Scenario {
        name: "decode_heavy",
        clients: vec![
            ClientSpec::fixed(Arrival::Deterministic, ArrivalProcess::Constant(0.4), 64, 1800),
            ClientSpec::fixed(Arrival::Deterministic, ArrivalProcess::Constant(0.2), 64, 2400),
        ],
        duration: 150.0,
    }
}

fn main() {
    let mut b = Bench::from_args().quick();
    let mut extra: Vec<(String, f64)> = Vec::new();

    // ---- macro vs micro on the decode-heavy trace ----
    let trace = generate(&decode_heavy(), 42);
    let n = trace.len() as u64;
    let mut cfg = SimConfig::a100_7b_vllm();
    cfg.sample_dt = 5.0; // windowed sampling is an event horizon; don't let it dominate
    for (name, sched, pred) in [
        ("fcfs+oracle", SchedKind::Fcfs, PredKind::Oracle),
        ("vtc+oracle", SchedKind::Vtc, PredKind::Oracle),
        ("equinox+mope", SchedKind::Equinox, PredKind::Mope),
    ] {
        for mode in [StepMode::Micro, StepMode::Macro] {
            let tag = if mode == StepMode::Macro { "macro" } else { "micro" };
            b.run_throughput(&format!("sim/decode_heavy/{name}/{tag}"), n, || {
                let r = run_sim_stepped(&cfg, mode, sched, pred, &trace, 42);
                assert_eq!(r.finished, trace.len());
            });
        }
        // Speedup accounting only when both throughput rows actually ran
        // (a `cargo bench -- <filter>` that excludes them must not pay
        // two extra full simulations or write zeroed speedups into the
        // trajectory JSON).
        let get = |t: &str| {
            b.results
                .iter()
                .find(|(nm, _)| nm == &format!("sim/decode_heavy/{name}/{t}"))
                .map(|(_, v)| *v)
        };
        let (Some(macro_rate), Some(micro_rate)) = (get("macro"), get("micro")) else {
            continue;
        };
        let micro = run_sim_stepped(&cfg, StepMode::Micro, sched, pred, &trace, 42);
        let mac = run_sim_stepped(&cfg, StepMode::Macro, sched, pred, &trace, 42);
        assert_eq!(micro.finished, mac.finished);
        if micro.iter_equiv != mac.iter_equiv {
            eprintln!(
                "WARN {name}: iter_equiv diverged ({} vs {}) — see tests/macro_stepping.rs",
                micro.iter_equiv, mac.iter_equiv
            );
        }
        let iter_ratio = micro.iterations as f64 / mac.iterations.max(1) as f64;
        let wall_speedup = macro_rate / micro_rate.max(1e-9);
        println!(
            "speedup sim/decode_heavy/{name}: {wall_speedup:.1}x wall-clock; engine iterations \
             {} -> {} ({iter_ratio:.1}x fewer; {} macro-steps)",
            micro.iterations, mac.iterations, mac.macro_steps
        );
        extra.push((format!("sim/decode_heavy/{name}/micro_iterations"), micro.iterations as f64));
        extra.push((format!("sim/decode_heavy/{name}/macro_iterations"), mac.iterations as f64));
        extra.push((format!("sim/decode_heavy/{name}/iteration_ratio"), iter_ratio));
        extra.push((format!("sim/decode_heavy/{name}/wall_speedup"), wall_speedup));
    }

    // ---- legacy mixed workload (macro default), for trend continuity ----
    let trace = generate(&Scenario::balanced_load(60.0), 42);
    let n = trace.len() as u64;
    let cfg = SimConfig::a100_7b_vllm().with_host(HostProfile::SLORA);
    for (name, sched, pred) in [
        ("sim/fcfs+oracle", SchedKind::Fcfs, PredKind::Oracle),
        ("sim/vtc+oracle", SchedKind::Vtc, PredKind::Oracle),
        ("sim/equinox+mope", SchedKind::Equinox, PredKind::Mope),
    ] {
        b.run_throughput(name, n, || {
            let r = run_sim(&cfg, sched, pred, &trace, 42);
            assert_eq!(r.finished, trace.len());
        });
    }

    // GPU cost model alone (varying input so the optimiser can't fold it).
    let gpu = equinox::sim::GpuModel::a100_7b();
    let mut ctx = 0u64;
    b.run("gpu_model/iteration", || {
        ctx = (ctx + 17) % 2048;
        let mix = equinox::sim::gpu::IterationMix {
            prefill_tokens: 256 + ctx % 512,
            prefill_context: 4 * ctx,
            decode_seqs: 1 + ctx % 128,
            decode_context: (1 + ctx % 128) * (256 + ctx),
        };
        equinox::util::bench::black_box(gpu.iteration(&mix).time)
    });
    // Closed-form bulk costing: must stay O(1)-ish in k.
    let mut k = 1u64;
    b.run("gpu_model/iterations_bulk_10k", || {
        k = k % 9000 + 1000;
        let mix = equinox::sim::gpu::IterationMix {
            decode_seqs: 32,
            decode_context: 32 * 700,
            ..Default::default()
        };
        equinox::util::bench::black_box(gpu.iterations_bulk(&mix, k).time)
    });

    // Machine-readable trajectory (same shape as BENCH_scheduler.json).
    let mut obj = Json::obj();
    for (name, v) in b.results.iter().chain(extra.iter()) {
        obj = obj.set(name, *v);
    }
    let entries = b.results.len() + extra.len();
    match std::fs::write("BENCH_simulator.json", obj.to_string()) {
        Ok(()) => println!("wrote BENCH_simulator.json ({entries} entries)"),
        Err(e) => eprintln!("BENCH_simulator.json not written: {e}"),
    }
}
