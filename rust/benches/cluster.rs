//! Cluster-layer benchmarks: driver interleaving overhead per replica
//! (cluster-of-1 vs the plain engine, then N∈{1,4,16}), parallel-driver
//! scale-out (serial vs `DriveMode::Parallel{8}` wall clock at
//! N∈{4,16,64} replicas), fault-plane overhead (clean vs crash-recover
//! at N∈{4,16}), scale-event overhead (static vs scheduled grow/drain
//! at N∈{4,16}), and router pick cost at 10k tenants. Results
//! land in `BENCH_cluster.json` so the perf trajectory is tracked across
//! PRs (EXPERIMENTS.md §Cluster, §Parallel driver).

use equinox::cluster::{
    run_cluster, AutoscalePolicy, ClusterOpts, ClusterView, DriveMode, FaultPlan, Fleet,
    ReplicaSpec, ReplicaView, RouterKind, ScaleEvent,
};
use equinox::cluster::GlobalPlane;
use equinox::core::{ClientId, Request, RequestId};
use equinox::exp::{run_sim, PredKind, SchedKind};
use equinox::sched::HfParams;
use equinox::sim::SimConfig;
use equinox::util::bench::{black_box, Bench};
use equinox::util::json::Json;
use equinox::workload::{generate, Scenario, Trace};

fn homo_fleet(n: usize) -> Fleet {
    Fleet { name: format!("bench{n}"), replicas: (0..n).map(|_| ReplicaSpec::a100_40g()).collect() }
}

/// Wall-clock one full cluster run (ns), best of up to 3 within a ~1.5 s
/// budget — these runs are far too long for the calibrated ns/op loop.
fn cluster_wall_ns(n: usize, trace: &Trace, drive: DriveMode) -> f64 {
    let mut best = f64::INFINITY;
    let mut spent = 0.0f64;
    for _ in 0..3 {
        let t = std::time::Instant::now();
        let opts = ClusterOpts::new(42).with_drive(drive);
        let res = run_cluster(
            homo_fleet(n),
            RouterKind::FairShare.make(),
            SchedKind::Equinox,
            PredKind::Mope,
            trace,
            &opts,
        );
        black_box(res.finished());
        let ns = t.elapsed().as_nanos() as f64;
        best = best.min(ns);
        spent += ns;
        if spent > 1.5e9 {
            break;
        }
    }
    best
}

fn main() {
    let mut b = Bench::from_args().quick();

    // ---- driver overhead per replica ----
    // Same per-replica offered load at every N (rates scale with the
    // fleet), so the wall-time ratio cluster/N÷plain is the driver's
    // interleaving overhead per replica. The plain baseline runs the
    // SAME A100-40GB hardware profile as the fleet replicas — comparing
    // against the 80GB default would report the GPU speed difference as
    // driver overhead.
    let plain_trace = generate(&Scenario::balanced_load(10.0), 42);
    let baseline_cfg = ReplicaSpec::a100_40g().sim_config(&SimConfig::a100_7b_vllm());
    b.run("cluster/plain-engine-baseline", || {
        let res = run_sim(&baseline_cfg, SchedKind::Equinox, PredKind::Mope, &plain_trace, 42);
        black_box(res.finished)
    });
    for n in [1usize, 4, 16] {
        let trace = generate(&Scenario::balanced_load(10.0).scale_rates(n as f64), 42);
        let name = format!("cluster/driver/n{n}");
        b.run(&name, || {
            let opts = ClusterOpts::new(42);
            let res = run_cluster(
                homo_fleet(n),
                RouterKind::FairShare.make(),
                SchedKind::Equinox,
                PredKind::Mope,
                &trace,
                &opts,
            );
            black_box(res.finished())
        });
    }
    // Human-readable overhead line: solo cluster vs plain engine.
    let plain = b.results.iter().find(|(n, _)| n == "cluster/plain-engine-baseline").map(|(_, v)| *v);
    let solo = b.results.iter().find(|(n, _)| n == "cluster/driver/n1").map(|(_, v)| *v);
    if let (Some(p), Some(s)) = (plain, solo) {
        println!(
            "driver overhead: cluster-of-1 runs at {:.2}x the plain engine ({:.1} ms vs {:.1} ms)",
            s / p.max(1e-9),
            s / 1e6,
            p / 1e6
        );
    }

    // ---- parallel scale-out: serial vs parallel wall clock ----
    // Same per-replica offered load at every N (rates scale with the
    // fleet), so serial wall clock grows ~linearly with N while the
    // parallel driver amortises it over the worker pool. The acceptance
    // bar this seeds: ≥2× at N=16 with 8 threads. Both drives produce
    // bit-identical results (tests/parallel_driver.rs), so this measures
    // pure execution cost.
    for n in [4usize, 16, 64] {
        let trace = generate(&Scenario::balanced_load(6.0).scale_rates(n as f64), 42);
        let serial_ns = cluster_wall_ns(n, &trace, DriveMode::Serial);
        let par_ns = cluster_wall_ns(n, &trace, DriveMode::Parallel { threads: 8 });
        let speedup = serial_ns / par_ns.max(1.0);
        b.results.push((format!("cluster/scale/n{n}/serial"), serial_ns));
        b.results.push((format!("cluster/scale/n{n}/parallel8"), par_ns));
        // Stored as a ratio, not ns/op — the cross-PR trajectory line.
        b.results.push((format!("cluster/scale/n{n}/speedup"), speedup));
        println!(
            "scale-out n={n}: serial {:.1} ms, parallel(8) {:.1} ms — {speedup:.2}x",
            serial_ns / 1e6,
            par_ns / 1e6
        );
    }

    // ---- fault-plane overhead ----
    // Same trace with and without a crash-recover plan: the delta is the
    // cost of barrier fault checks + orphan extraction/migration. The
    // ratio is the cross-PR trajectory line; it should stay near 1.0 —
    // a fault plan is a handful of transitions, not a per-step tax.
    for n in [4usize, 16] {
        let trace = generate(&Scenario::balanced_load(6.0).scale_rates(n as f64), 42);
        let clean_ns = cluster_wall_ns(n, &trace, DriveMode::Serial);
        let mut best = f64::INFINITY;
        let mut spent = 0.0f64;
        for _ in 0..3 {
            let t = std::time::Instant::now();
            let opts = ClusterOpts::new(42)
                .with_faults(FaultPlan::crash_recover(0, 2.5, 6.0));
            let res = run_cluster(
                homo_fleet(n),
                RouterKind::FairShare.make(),
                SchedKind::Equinox,
                PredKind::Mope,
                &trace,
                &opts,
            );
            black_box(res.finished());
            let ns = t.elapsed().as_nanos() as f64;
            best = best.min(ns);
            spent += ns;
            if spent > 1.5e9 {
                break;
            }
        }
        let ratio = best / clean_ns.max(1.0);
        b.results.push((format!("cluster/faults/n{n}/clean"), clean_ns));
        b.results.push((format!("cluster/faults/n{n}/crash_recover"), best));
        b.results.push((format!("cluster/faults/n{n}/overhead"), ratio));
        println!(
            "fault plan n={n}: clean {:.1} ms, crash-recover {:.1} ms — {ratio:.2}x",
            clean_ns / 1e6,
            best / 1e6
        );
    }

    // ---- scale-event overhead ----
    // Same trace with and without a grow/drain schedule: the delta is
    // the cost of barrier scale checks + mid-run replica instantiation +
    // the retirement drain through orphan migration. The ratio is the
    // cross-PR trajectory line; it should stay near 1.0 — a scale plan
    // is two composition changes, not a per-step tax.
    for n in [4usize, 16] {
        let trace = generate(&Scenario::balanced_load(6.0).scale_rates(n as f64), 42);
        let static_ns = cluster_wall_ns(n, &trace, DriveMode::Serial);
        let mut best = f64::INFINITY;
        let mut spent = 0.0f64;
        for _ in 0..3 {
            let t = std::time::Instant::now();
            let opts = ClusterOpts::new(42).with_autoscale(AutoscalePolicy::Schedule(vec![
                ScaleEvent::grow(1.5, ReplicaSpec::a100_40g()),
                ScaleEvent::shrink(4.5),
            ]));
            let res = run_cluster(
                homo_fleet(n),
                RouterKind::FairShare.make(),
                SchedKind::Equinox,
                PredKind::Mope,
                &trace,
                &opts,
            );
            black_box(res.finished());
            let ns = t.elapsed().as_nanos() as f64;
            best = best.min(ns);
            spent += ns;
            if spent > 1.5e9 {
                break;
            }
        }
        let ratio = best / static_ns.max(1.0);
        b.results.push((format!("cluster/scale-events/n{n}/static"), static_ns));
        b.results.push((format!("cluster/scale-events/n{n}/scheduled"), best));
        b.results.push((format!("cluster/scale-events/n{n}/overhead"), ratio));
        println!(
            "scale events n={n}: static {:.1} ms, grow+drain {:.1} ms — {ratio:.2}x",
            static_ns / 1e6,
            best / 1e6
        );
    }

    // ---- router pick cost at 10k tenants ----
    let replicas: Vec<ReplicaView> = (0..8)
        .map(|id| ReplicaView {
            id,
            clock: 100.0,
            queued: 40 + id * 7,
            running: 32,
            outstanding_weighted: 30_000.0 + id as f64 * 4_000.0,
            kv_free_tokens: if id % 3 == 0 { 256 } else { 1 << 20 },
            kv_total_tokens: 1 << 20,
            peak_weighted_tps: if id % 2 == 0 { 18_000.0 } else { 14_000.0 },
            max_batch: 256,
            alive: true,
            slowdown: 1.0,
        })
        .collect();
    // Populate the plane with 10k known tenants so FairShare's sticky /
    // underserved path is the one measured (an empty plane marks every
    // client underserved and skips affinity entirely).
    let mut plane = GlobalPlane::new(8, 1.0, HfParams::default());
    {
        use equinox::sched::{Scheduler, Vtc};
        let mut seeder = Vtc::new();
        for c in 0..10_000u32 {
            seeder.enqueue(
                Request::new(RequestId(1_000_000 + c as u64), ClientId(c), 64 + c % 512, 8, 0.0),
                0.0,
            );
            let _ = seeder.pick(0.0, &mut |_| true);
        }
        plane.pull_replica(0, &seeder);
        plane.finish_sync(1.0);
    }
    for kind in [
        RouterKind::RoundRobin,
        RouterKind::JoinShortestQueue,
        RouterKind::PredictedCost,
        RouterKind::FairShare,
    ] {
        let mut router = kind.make();
        // Warm 10k sticky entries (FairShare) / exercise the same client
        // id distribution for all policies.
        let mut id = 0u64;
        for c in 0..10_000u32 {
            let req = Request::new(RequestId(id), ClientId(c), 64, 64, 0.0);
            id += 1;
            let view = ClusterView { replicas: &replicas, global: &plane };
            black_box(router.route(&req, 64, 320.0, &view));
        }
        let name = format!("cluster/route/{}@10k-tenants", kind.label());
        b.run(&name, || {
            let c = (id % 10_000) as u32;
            let req = Request::new(RequestId(id), ClientId(c), 64, 64, 0.0);
            id += 1;
            let view = ClusterView { replicas: &replicas, global: &plane };
            black_box(router.route(&req, 64, 320.0, &view))
        });
    }

    // Machine-readable trajectory: name → median ns/op.
    let mut obj = Json::obj();
    for (name, ns) in &b.results {
        obj = obj.set(name, *ns);
    }
    match std::fs::write("BENCH_cluster.json", obj.to_string()) {
        Ok(()) => println!("wrote BENCH_cluster.json ({} entries)", b.results.len()),
        Err(e) => eprintln!("BENCH_cluster.json not written: {e}"),
    }
}
