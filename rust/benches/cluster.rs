//! Cluster-layer benchmarks: driver interleaving overhead per replica
//! (cluster-of-1 vs the plain engine, then N∈{1,4,16}) and router pick
//! cost at 10k tenants. Results land in `BENCH_cluster.json` so the perf
//! trajectory is tracked across PRs (EXPERIMENTS.md §Cluster).

use equinox::cluster::{run_cluster, ClusterOpts, ClusterView, Fleet, ReplicaSpec, ReplicaView, RouterKind};
use equinox::cluster::GlobalPlane;
use equinox::core::{ClientId, Request, RequestId};
use equinox::exp::{run_sim, PredKind, SchedKind};
use equinox::sched::HfParams;
use equinox::sim::SimConfig;
use equinox::util::bench::{black_box, Bench};
use equinox::util::json::Json;
use equinox::workload::{generate, Scenario};

fn homo_fleet(n: usize) -> Fleet {
    Fleet { name: format!("bench{n}"), replicas: (0..n).map(|_| ReplicaSpec::a100_40g()).collect() }
}

fn main() {
    let mut b = Bench::from_args().quick();

    // ---- driver overhead per replica ----
    // Same per-replica offered load at every N (rates scale with the
    // fleet), so the wall-time ratio cluster/N÷plain is the driver's
    // interleaving overhead per replica. The plain baseline runs the
    // SAME A100-40GB hardware profile as the fleet replicas — comparing
    // against the 80GB default would report the GPU speed difference as
    // driver overhead.
    let plain_trace = generate(&Scenario::balanced_load(10.0), 42);
    let baseline_cfg = ReplicaSpec::a100_40g().sim_config(&SimConfig::a100_7b_vllm());
    b.run("cluster/plain-engine-baseline", || {
        let res = run_sim(&baseline_cfg, SchedKind::Equinox, PredKind::Mope, &plain_trace, 42);
        black_box(res.finished)
    });
    for n in [1usize, 4, 16] {
        let trace = generate(&Scenario::balanced_load(10.0).scale_rates(n as f64), 42);
        let name = format!("cluster/driver/n{n}");
        b.run(&name, || {
            let opts = ClusterOpts::new(42);
            let res = run_cluster(
                homo_fleet(n),
                RouterKind::FairShare.make(),
                SchedKind::Equinox,
                PredKind::Mope,
                &trace,
                &opts,
            );
            black_box(res.finished())
        });
    }
    // Human-readable overhead line: solo cluster vs plain engine.
    let plain = b.results.iter().find(|(n, _)| n == "cluster/plain-engine-baseline").map(|(_, v)| *v);
    let solo = b.results.iter().find(|(n, _)| n == "cluster/driver/n1").map(|(_, v)| *v);
    if let (Some(p), Some(s)) = (plain, solo) {
        println!(
            "driver overhead: cluster-of-1 runs at {:.2}x the plain engine ({:.1} ms vs {:.1} ms)",
            s / p.max(1e-9),
            s / 1e6,
            p / 1e6
        );
    }

    // ---- router pick cost at 10k tenants ----
    let replicas: Vec<ReplicaView> = (0..8)
        .map(|id| ReplicaView {
            id,
            clock: 100.0,
            queued: 40 + id * 7,
            running: 32,
            outstanding_weighted: 30_000.0 + id as f64 * 4_000.0,
            kv_free_tokens: if id % 3 == 0 { 256 } else { 1 << 20 },
            kv_total_tokens: 1 << 20,
            peak_weighted_tps: if id % 2 == 0 { 18_000.0 } else { 14_000.0 },
            max_batch: 256,
        })
        .collect();
    // Populate the plane with 10k known tenants so FairShare's sticky /
    // underserved path is the one measured (an empty plane marks every
    // client underserved and skips affinity entirely).
    let mut plane = GlobalPlane::new(8, 1.0, HfParams::default());
    {
        use equinox::sched::{Scheduler, Vtc};
        let mut seeder = Vtc::new();
        for c in 0..10_000u32 {
            seeder.enqueue(
                Request::new(RequestId(1_000_000 + c as u64), ClientId(c), 64 + c % 512, 8, 0.0),
                0.0,
            );
            let _ = seeder.pick(0.0, &mut |_| true);
        }
        plane.pull_replica(0, &seeder);
        plane.finish_sync(1.0);
    }
    for kind in [
        RouterKind::RoundRobin,
        RouterKind::JoinShortestQueue,
        RouterKind::PredictedCost,
        RouterKind::FairShare,
    ] {
        let mut router = kind.make();
        // Warm 10k sticky entries (FairShare) / exercise the same client
        // id distribution for all policies.
        let mut id = 0u64;
        for c in 0..10_000u32 {
            let req = Request::new(RequestId(id), ClientId(c), 64, 64, 0.0);
            id += 1;
            let view = ClusterView { replicas: &replicas, global: &plane };
            black_box(router.route(&req, 64, 320.0, &view));
        }
        let name = format!("cluster/route/{}@10k-tenants", kind.label());
        b.run(&name, || {
            let c = (id % 10_000) as u32;
            let req = Request::new(RequestId(id), ClientId(c), 64, 64, 0.0);
            id += 1;
            let view = ClusterView { replicas: &replicas, global: &plane };
            black_box(router.route(&req, 64, 320.0, &view))
        });
    }

    // Machine-readable trajectory: name → median ns/op.
    let mut obj = Json::obj();
    for (name, ns) in &b.results {
        obj = obj.set(name, *ns);
    }
    match std::fs::write("BENCH_cluster.json", obj.to_string()) {
        Ok(()) => println!("wrote BENCH_cluster.json ({} entries)", b.results.len()),
        Err(e) => eprintln!("BENCH_cluster.json not written: {e}"),
    }
}
