//! L3 hot-path microbenchmarks: scheduler decision latency. The pick loop
//! runs once per engine iteration (and once per admission in the real
//! service) — it must stay in the low microseconds even with thousands of
//! tenants queued. See EXPERIMENTS.md §Perf for methodology and the
//! recorded tenant-scaling table.
//!
//! The indexed schedulers (`vtc`, `equinox`) are measured against the
//! retained linear-scan references (`vtc-linear`, `equinox-linear`) in
//! the same run, so the speedup is an apples-to-apples measurement, and
//! every result is dumped to `BENCH_scheduler.json` (name → ns/op) so
//! the perf trajectory is tracked across PRs.

use equinox::core::{ClientId, Request, RequestId};
use equinox::sched::{
    Actuals, EquinoxSched, Fcfs, LinearEquinox, LinearVtc, Scheduler, Vtc,
};
use equinox::util::bench::{black_box, Bench};
use equinox::util::json::Json;
use equinox::util::rng::Rng;

fn filled(sched: &mut dyn Scheduler, clients: u32, per_client: u64, rng: &mut Rng) {
    let mut id = 0u64;
    for c in 0..clients {
        for _ in 0..per_client {
            let mut r = Request::new(
                RequestId(id),
                ClientId(c),
                rng.range(16, 512) as u32,
                rng.range(16, 512) as u32,
                0.0,
            );
            r.predicted_output_tokens = r.true_output_tokens;
            r.predicted_latency = 1.0;
            r.predicted_tps = 1000.0;
            r.predicted_gpu_util = 0.8;
            id += 1;
            sched.enqueue(r, 0.0);
        }
    }
}

/// Backlog depth per tenant: deep at small scale, shallow at 10k+, one
/// at 100k+ so the resident set stays sane (a million queued requests is
/// already ~hundreds of MB) while queues never drain mid-measurement —
/// the pick+complete cycle recycles every picked request.
fn per_client_depth(clients: u32) -> u64 {
    match clients {
        0..=256 => 64,
        257..=4096 => 8,
        4097..=65536 => 4,
        _ => 1,
    }
}

fn bench_policy(
    b: &mut Bench,
    name: &str,
    mut make: impl FnMut() -> Box<dyn Scheduler>,
    clients: u32,
) {
    let mut rng = Rng::new(7);
    // pick+complete cycle: steady-state decision cost.
    let mut sched = make();
    filled(sched.as_mut(), clients, per_client_depth(clients), &mut rng);
    let actuals = Actuals { latency: 1.0, gpu_util: 0.8, tps: 1000.0, output_tokens: 64 };
    b.run(&format!("{name}/pick+complete/{clients}c"), || {
        if let Some(r) = sched.pick(1.0, &mut |_| true) {
            sched.on_complete(&r, &actuals, 2.0);
            // Recycle so the queue never drains.
            let mut r2 = r.clone();
            r2.arrival += 1.0;
            sched.enqueue(r2, 2.0);
        }
        black_box(sched.queue_len())
    });
}

fn report_speedup(b: &Bench, policy: &str, clients: u32) {
    let get = |name: &str| b.results.iter().find(|(n, _)| n == name).map(|(_, v)| *v);
    let indexed = get(&format!("{policy}/pick+complete/{clients}c"));
    let linear = get(&format!("{policy}-linear/pick+complete/{clients}c"));
    if let (Some(ix), Some(lin)) = (indexed, linear) {
        println!(
            "speedup {policy}@{clients}c: {:.1}x (indexed {:.0} ns vs linear-scan {:.0} ns)",
            lin / ix.max(1e-9),
            ix,
            lin
        );
    }
}

fn main() {
    let mut b = Bench::from_args();
    // Tenant scaling: the indexed pick must stay flat-ish while the
    // retained linear-scan reference grows with C. The top of the sweep
    // is a full million tenants — per-client state lives in dense
    // `ClientSlab` storage, so the decision cost is a handful of array
    // probes plus the O(log C) ordered-index ops regardless of C.
    for clients in [2u32, 16, 256, 4096, 16384, 1_048_576] {
        bench_policy(&mut b, "fcfs", || Box::new(Fcfs::new()), clients);
        bench_policy(&mut b, "vtc", || Box::new(Vtc::new()), clients);
        bench_policy(&mut b, "equinox", || Box::new(EquinoxSched::default_params(3000.0)), clients);
    }
    // Linear-scan references at the comparison points (16384 omitted:
    // setup alone is O(C²) for the linear lift — the point is made at
    // 4096, where the acceptance bar is ≥10×).
    for clients in [256u32, 4096] {
        bench_policy(&mut b, "vtc-linear", || Box::new(LinearVtc::new()), clients);
        bench_policy(&mut b, "equinox-linear", || {
            Box::new(LinearEquinox::default_params(3000.0))
        }, clients);
    }

    // Enqueue path (reactivation lift + index insert).
    let mut rng = Rng::new(9);
    let mut sched = EquinoxSched::default_params(3000.0);
    let mut id = 0u64;
    b.run("equinox/enqueue", || {
        let mut r = Request::new(RequestId(id), ClientId((id % 64) as u32), 64, 64, 0.0);
        r.predicted_output_tokens = 64;
        id += 1;
        sched.enqueue(r, 0.0);
        if id % 4096 == 0 {
            // Drain to bound memory.
            while sched.pick(0.0, &mut |_| true).is_some() {}
        }
        black_box(rng.next_u64())
    });

    for policy in ["vtc", "equinox"] {
        report_speedup(&b, policy, 256);
        report_speedup(&b, policy, 4096);
    }

    // Machine-readable trajectory: name → median ns/op.
    let mut obj = Json::obj();
    for (name, ns) in &b.results {
        obj = obj.set(name, *ns);
    }
    match std::fs::write("BENCH_scheduler.json", obj.to_string()) {
        Ok(()) => println!("wrote BENCH_scheduler.json ({} entries)", b.results.len()),
        Err(e) => eprintln!("BENCH_scheduler.json not written: {e}"),
    }
}
