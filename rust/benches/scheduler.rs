//! L3 hot-path microbenchmarks: scheduler decision latency. The pick loop
//! runs once per engine iteration (and once per admission in the real
//! service) — it must stay in the low microseconds even with hundreds of
//! tenants queued. See EXPERIMENTS.md §Perf.

use equinox::core::{ClientId, Request, RequestId};
use equinox::sched::{Actuals, EquinoxSched, Fcfs, Scheduler, Vtc};
use equinox::util::bench::{black_box, Bench};
use equinox::util::rng::Rng;

fn filled(sched: &mut dyn Scheduler, clients: u32, per_client: u64, rng: &mut Rng) {
    let mut id = 0u64;
    for c in 0..clients {
        for _ in 0..per_client {
            let mut r = Request::new(
                RequestId(id),
                ClientId(c),
                rng.range(16, 512) as u32,
                rng.range(16, 512) as u32,
                0.0,
            );
            r.predicted_output_tokens = r.true_output_tokens;
            r.predicted_latency = 1.0;
            r.predicted_tps = 1000.0;
            r.predicted_gpu_util = 0.8;
            id += 1;
            sched.enqueue(r, 0.0);
        }
    }
}

fn bench_policy(b: &mut Bench, name: &str, mut make: impl FnMut() -> Box<dyn Scheduler>, clients: u32) {
    let mut rng = Rng::new(7);
    // pick+complete cycle: steady-state decision cost.
    let mut sched = make();
    filled(sched.as_mut(), clients, 64, &mut rng);
    let actuals = Actuals { latency: 1.0, gpu_util: 0.8, tps: 1000.0, output_tokens: 64 };
    b.run(&format!("{name}/pick+complete/{clients}c"), || {
        if let Some(r) = sched.pick(1.0, &mut |_| true) {
            sched.on_complete(&r, &actuals, 2.0);
            // Recycle so the queue never drains.
            let mut r2 = r.clone();
            r2.arrival += 1.0;
            sched.enqueue(r2, 2.0);
        }
        black_box(sched.queue_len())
    });
}

fn main() {
    let mut b = Bench::from_args();
    for clients in [2u32, 16, 256] {
        bench_policy(&mut b, "fcfs", || Box::new(Fcfs::new()), clients);
        bench_policy(&mut b, "vtc", || Box::new(Vtc::new()), clients);
        bench_policy(&mut b, "equinox", || Box::new(EquinoxSched::default_params(3000.0)), clients);
    }
    // Enqueue path.
    let mut rng = Rng::new(9);
    let mut sched = EquinoxSched::default_params(3000.0);
    let mut id = 0u64;
    b.run("equinox/enqueue", || {
        let mut r = Request::new(RequestId(id), ClientId((id % 64) as u32), 64, 64, 0.0);
        r.predicted_output_tokens = 64;
        id += 1;
        sched.enqueue(r, 0.0);
        if id % 4096 == 0 {
            // Drain to bound memory.
            while sched.pick(0.0, &mut |_| true).is_some() {}
        }
        black_box(rng.next_u64())
    });
}
